"""Request scheduler: queue `SelectRequest`s, micro-batch same-pool solves.

The serving shape this implements (DESIGN.md §6): clients ``submit()``
and get a ticket back immediately (admission control runs here — see
``serve/admission.py``); ``drain()`` executes the queue.  Execution groups
queued requests by **batch key** ``(pool_id, strategy, k, lam, eps,
positive)`` — requests that are the *same solve over the same pool up to
their target/validity vectors* — and runs each group as one
``omp_select_batched`` call: one column-cache/Gram growth schedule and one
pool scan per round serve the whole group, so B queued requests cost one
batched solve instead of B sequential ones (benchmarks/bench_selection.py
``run_serve`` records the throughput ratio; acceptance ≥ 5x at B = 32).

Batch sizes are padded up to a power-of-two bucket (extra rows re-solve
request 0 and are dropped) so the jit cache holds O(log max_batch)
programs instead of one per observed batch size.

Non-batchable work degrades gracefully to per-request execution: CRAIG
tiers reuse the registry's cached FL scan, chunked pools run the
streaming block-OMP, everything else goes through the ordinary
``selection.select`` dispatch.  Results are per-ticket ``SelectionResult``
(weights re-normalized per request, exactly as the library path returns).

Resilience (DESIGN.md §8): requests carry optional deadlines (expired
tickets fail fast as ``timeout`` without burning a solve); chunked solves
run under a bounded-retry policy with optional mid-solve checkpoints; a
per-pool circuit breaker fails a poisoned pool fast instead of wedging
the queue; and when a certified streaming solve cannot be had, the
scheduler walks the graceful-degradation ladder (resume → anytime-prefix
→ stochastic fallback), recording the rung on ``Ticket.degradation``.

Overload (DESIGN.md §10): requests carry a **priority class**
(interactive > batch > best-effort) and drain is no longer FIFO — within
the highest queued class, tenants are served by deficit round robin
weighted by ``TenantAccount.weight``, so one hot tenant cannot starve
the rest.  An ``OverloadController`` watches queue depth: under brownout
it sheds best-effort at submit (a labelled ``"shed"`` ticket, never
charged) and routes same-pool differing-k gradmatch groups through one
**shared anytime session** — each request answered as a bit-exact index
prefix of the deepest k (rung ``"prefix-shared"``); under full overload
non-interactive gradmatch drops to the stochastic rung.  Deadlines are
validated at submit (``deadline_s <= 0`` is rejected immediately) and
checked again per group at drain.  Pools admitted with deferred warming
are skipped by the fair scan until warm — their admission pass advances
only when nothing else is runnable, so it never head-of-line-blocks.
"""

from __future__ import annotations

import hashlib
import itertools
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import craig as craig_lib
from repro.core import glister as glister_lib
from repro.core import partition as part_lib
from repro.core import random_sel
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import (omp_select_batched, omp_session_start,
                            session_prefix_result, session_result)
from repro.resilience.circuit import BreakerBoard, CircuitOpen
from repro.resilience.degrade import (DeadlineExceeded,
                                      stochastic_fallback,
                                      stochastic_pool_select)
from repro.resilience.faults import FaultError
from repro.resilience.recovery import RetryPolicy
from repro.serve.admission import (AdmissionController, OverloadController,
                                   estimate_cost)
from repro.serve.registry import PoolEntry, PoolRegistry, UnknownPool

SERVABLE = ("gradmatch", "gradmatch-partitioned", "craig", "craig-lazy",
            "craig-stochastic", "glister", "random")

# Strict priority order: a queued request of a higher class always drains
# before any lower class; fairness (DRR over tenants) applies *within*
# the class.  The overload controller sheds from the right.
PRIORITIES = ("interactive", "batch", "best-effort")

_CRAIG_METHODS = {"craig": "dense", "craig-lazy": "lazy",
                  "craig-stochastic": "stochastic"}


@dataclass(frozen=True)
class SelectRequest:
    """One selection ask.  ``target=None`` means the pool's cached default
    (the eq.-2 sum); a per-request ``valid`` intersects the pool's own."""

    pool_id: str
    k: int
    strategy: str = "gradmatch"
    lam: float = 0.5
    eps: float = 1e-10
    positive: bool = True
    target: Optional[object] = None     # (d,) array-like
    valid: Optional[object] = None      # (n,) bool array-like
    tenant: str = "default"
    seed: int = 0                       # random / craig-stochastic
    deadline_s: Optional[float] = None  # fail fast past this queue age
    priority: str = "interactive"       # see PRIORITIES

    def batch_key(self):
        # deadline_s deliberately excluded: it shapes *when* a ticket may
        # still run, not *what* solve it is.
        return (self.pool_id, self.strategy, self.k, float(self.lam),
                float(self.eps), self.positive)


@dataclass
class Ticket:
    ticket_id: str
    request: SelectRequest
    cost: float
    status: str = "queued"              # queued | done | failed | shed
    result: Optional[SelectionResult] = None
    error: Optional[str] = None
    batched_with: int = 0               # group size the solve ran at
    degradation: str = "none"           # rung served (resilience.DEGRADE_LEVELS)
    submitted_at: float = 0.0           # scheduler clock at submit()


def _bucket_b(b: int) -> int:
    p = 1
    while p < b:
        p *= 2
    return p


class RequestScheduler:
    def __init__(self, registry: PoolRegistry,
                 admission: Optional[AdmissionController] = None,
                 max_batch: int = 32,
                 stream_buffer: int = 256,
                 clock: Callable[[], float] = time.monotonic,
                 retry: Optional[RetryPolicy] = None,
                 breakers: Optional[BreakerBoard] = None,
                 checkpoint_root: Optional[str] = None,
                 checkpoint_every: int = 8,
                 degrade: bool = True,
                 session_lookup: Optional[Callable] = None,
                 overload: Optional[OverloadController] = None,
                 session_save: Optional[Callable] = None,
                 warm_chunks: int = 8):
        self.registry = registry
        self.admission = admission or AdmissionController()
        self.max_batch = int(max_batch)
        self.stream_buffer = int(stream_buffer)
        self._clock = clock
        self.retry = retry
        self.breakers = breakers or BreakerBoard(clock=clock)
        self.checkpoint_root = checkpoint_root
        self.checkpoint_every = int(checkpoint_every)
        self.degrade = bool(degrade)
        # (pool_id, fingerprint, k) -> SelectionResult | None; wired by
        # SelectionService to its session store (anytime-prefix rung).
        self.session_lookup = session_lookup
        # Brownout machinery (DESIGN.md §10): the overload controller
        # decides shed/brownout levels; session_save(pool_id, fp, state)
        # parks a shared-solve session so later groups reuse it.
        self.overload = overload
        self.session_save = session_save
        self.warm_chunks = int(warm_chunks)
        self._queue: list[Ticket] = []
        self._ids = itertools.count()
        self.batches_run = 0
        self.singles_run = 0
        self.shared_solves = 0
        self.degraded_served = {}          # rung -> count
        # Shed-accounting invariant (load harness + parity gate):
        #   admitted == completed + shed + failed + pending
        # where "admitted" counts every ticket handed back to a caller
        # (queued or shed) and rejections raised at submit count nowhere.
        self.counters = {"admitted": 0, "shed": 0, "completed": 0,
                         "failed": 0, "timeouts": 0}
        # Deficit round robin state: tenant -> spendable work units, plus
        # the rotation order.  Pruned when a tenant's queue empties so a
        # returning tenant starts fresh instead of cashing stale credit.
        self._deficits: dict[str, float] = {}
        self._rr: list[str] = []

    # -- intake --------------------------------------------------------------
    def submit(self, req: SelectRequest) -> Ticket:
        if req.strategy not in SERVABLE:
            raise ValueError(
                f"unservable strategy {req.strategy!r}; servable: "
                f"{SERVABLE}")
        if req.k <= 0:
            raise ValueError(f"k must be positive, got {req.k}")
        if req.priority not in PRIORITIES:
            raise ValueError(
                f"unknown priority {req.priority!r}; one of {PRIORITIES}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            # Fail fast: deadline_s is relative to submit, so a <= 0
            # value is already expired — queueing it would only burn a
            # queue slot to be timed out at drain.
            raise ValueError(
                f"deadline_s must be > 0, got {req.deadline_s}: the "
                "deadline is measured from submit, so this request is "
                "already expired")
        entry = self.registry.get(req.pool_id)   # raises UnknownPool
        # Fail fast before charging the tenant: an open breaker means
        # this request would only queue behind a poisoned pool.
        self.breakers.get(req.pool_id).peek()    # raises CircuitOpen
        cost = estimate_cost(entry.n, entry.d, req.k)
        art_ticket = self._try_artifact(req, entry, cost)
        if art_ticket is not None:
            return art_ticket
        if self.overload is not None:
            self.overload.observe(len(self._queue))
            if self.overload.should_shed(req.priority):
                # Visible, labelled, never charged: the caller gets a
                # terminal "shed" ticket instead of an exception so the
                # response carries its degradation rung like any other.
                self.overload.record_shed(req.priority)
                self.counters["admitted"] += 1
                self.counters["shed"] += 1
                return Ticket(
                    ticket_id=f"req-{next(self._ids)}", request=req,
                    cost=cost, status="shed", degradation="shed",
                    error=(f"shed at submit: overload level "
                           f"{self.overload.level} sheds "
                           f"{req.priority!r} traffic"),
                    submitted_at=self._clock())
        self.admission.admit(req.tenant, cost, len(self._queue))
        self.counters["admitted"] += 1
        ticket = Ticket(ticket_id=f"req-{next(self._ids)}", request=req,
                        cost=cost, submitted_at=self._clock())
        self._queue.append(ticket)
        return ticket

    def _try_artifact(self, req: SelectRequest, entry: PoolEntry,
                      cost: float) -> Optional[Ticket]:
        """Answer a gradmatch ask from a verified offline artifact.

        Served *at submit*, off the drain path entirely: a hit is a dict
        probe plus an O(k) slice of the memoized trajectory — no queue
        slot, no admission charge, no pool scan — returned as a terminal
        ticket labelled ``degradation="artifact"``.  The served answer is
        bit-exact (indices, mask, normalized weights, err) to the live
        anytime session engine at this k, and index-identical to the
        one-shot ``omp_select`` wherever the two live paths agree — at
        very large pools their different padded solve widths can flip
        near-tie argmaxes, in which case the artifact sides with the
        session engine and matches the certified batched path at the
        objective level (DESIGN.md §12, parity_gate check 8).  Any miss,
        verification failure, or uncovered ask returns None and the
        request proceeds through the ordinary (live, certified) path —
        fail closed, never a corrupt result.

        Accounting mirrors shed tickets: ``admitted`` and ``completed``
        both count it, the tenant is never charged (nothing was queued),
        preserving ``admitted == completed + shed + failed + pending``.
        """
        if (req.strategy != "gradmatch" or req.valid is not None
                or not entry.batchable):
            return None
        target = (entry.target_sum if req.target is None else req.target)
        try:
            art = self.registry.artifact_lookup(
                entry, req.k, req.lam, req.eps, req.positive, target)
        except Exception:
            return None                  # lookup must never fail a submit
        if art is None:
            return None
        idx, w, mask, err = art.slice(req.k)
        w = jnp.asarray(w)
        mask_j = jnp.asarray(mask)
        ticket = Ticket(ticket_id=f"req-{next(self._ids)}", request=req,
                        cost=cost, submitted_at=self._clock())
        ticket.result = SelectionResult(
            jnp.asarray(idx), _normalize(w, mask_j), mask_j,
            jnp.asarray(err))
        self._served(ticket, "artifact")
        self.counters["admitted"] += 1
        self.counters["completed"] += 1
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    # -- execution -----------------------------------------------------------
    def drain(self) -> list[Ticket]:
        """Run the whole queue; returns the tickets in completion order.

        A failing request fails its ticket(s), never the queue: tenants
        get their in-flight slot back either way, and failed work refunds
        its admission charge (a metered tenant must not pay for
        selections that were never delivered).
        """
        done: list[Ticket] = []
        while self._queue:
            done.extend(self.drain_step())
        return done

    def drain_step(self) -> list[Ticket]:
        """Serve one scheduling quantum; returns the finalized tickets.

        One step = pick the fairness winner (strict priority class, then
        weighted deficit round robin over tenants), execute its group,
        settle admission.  The open-loop load harness interleaves steps
        with arrivals; ``drain()`` just loops this to empty.
        """
        if not self._queue:
            return []
        level = (self.overload.observe(len(self._queue))
                 if self.overload is not None else 0)
        head = self._fair_head()
        if head is None:
            # Every queued ticket waits on a warming pool: advance the
            # deferred admission pass and time out what expired — the
            # warm pass itself is the only runnable work.
            group = self._advance_warming()
        else:
            group = self._execute_head(head, level)
        for t in group:
            self._settle(t)
        return group

    def _execute_head(self, head: Ticket, level: int) -> list[Ticket]:
        req = head.request
        try:
            entry = self.registry.get(req.pool_id)
        except UnknownPool as exc:
            # Pool evicted between submit and drain: fail every ticket
            # queued against it (same fate at their own head position).
            group = self._take_group_by_pool(req.pool_id)
            for t in group:
                t.status = "failed"
                t.error = f"{type(exc).__name__}: {exc}"
            return group
        try:
            # The real admission through the breaker (submit only
            # peeks): an open pool fails its whole queued group
            # immediately — no solve, no retry burn, no wedge.
            self.breakers.get(req.pool_id).allow()
        except CircuitOpen as exc:
            group = self._take_group_by_pool(req.pool_id)
            for t in group:
                t.status = "failed"
                t.degradation = "failed"
                t.error = f"{type(exc).__name__}: {exc}"
            return group
        if entry.warm_state == "failed":
            group = self._take_group_by_pool(req.pool_id)
            for t in group:
                t.status = "failed"
                t.degradation = "failed"
                t.error = (f"pool admission warm failed: "
                           f"{entry.warm_error}")
            return group
        if (level >= 2 and self.degrade and req.strategy == "gradmatch"
                and req.priority != "interactive"):
            # Full overload: non-interactive gradmatch takes the
            # stochastic rung — a cheap subsample solve instead of the
            # real thing, labelled as such.
            self._queue.remove(head)
            group = [head]
            if self._expire_split(group):
                self._run_brownout_single(entry, head)
            self._charge_fair(group)
            return group
        if req.strategy == "gradmatch" and entry.batchable:
            if (level >= 1 and self.degrade and req.target is None
                    and req.valid is None):
                # Brownout: same-pool default-target gradmatch requests
                # of *any* k share one anytime session.
                group = self._take_share_group(head)
                live = self._expire_split(group)
                if live:
                    self._run_shared_anytime(entry, live)
            else:
                group = self._take_group(head)
                live = self._expire_split(group)
                if live:
                    self._run_gradmatch_batch(entry, live)
            self._charge_fair(group)
            return group
        self._queue.remove(head)
        group = [head]
        self._run_single(entry, head)   # checks its own deadline
        self._charge_fair(group)
        return group

    def _settle(self, t: Ticket) -> None:
        """Release the admission slot and keep the shed-accounting
        invariant: failed work (timeouts included) refunds its charge."""
        self.admission.complete(
            t.request.tenant,
            refund=t.cost if t.status == "failed" else 0.0)
        if t.status == "done":
            self.counters["completed"] += 1
        else:
            self.counters["failed"] += 1
            if t.degradation == "timeout":
                self.counters["timeouts"] += 1

    # -- fairness (DESIGN.md §10) --------------------------------------------
    def _runnable(self, t: Ticket) -> bool:
        entry = self.registry.peek(t.request.pool_id)
        return entry is None or entry.warm_state != "warming"

    def _fair_head(self) -> Optional[Ticket]:
        """Pick the next ticket: strict priority class first, weighted
        deficit round robin over tenants within the class, FIFO within a
        tenant.  Returns None when nothing is runnable (all queued pools
        still warming)."""
        runnable = [t for t in self._queue if self._runnable(t)]
        if not runnable:
            return None
        for cls in PRIORITIES:
            cand = [t for t in runnable if t.request.priority == cls]
            if cand:
                break
        heads: dict[str, Ticket] = {}
        for t in cand:
            heads.setdefault(t.request.tenant, t)
        queued_tenants = {t.request.tenant for t in self._queue}
        # Reset-on-empty: a tenant with no queued work loses its deficit
        # (and its rotation slot) — DRR credit must not accumulate while
        # idle, or a burst would replay the whole backlog unfairly.
        for tn in list(self._deficits):
            if tn not in queued_tenants:
                del self._deficits[tn]
        self._rr = [tn for tn in self._rr if tn in queued_tenants]
        for tn in heads:
            if tn not in self._rr:
                self._rr.append(tn)
        order = [tn for tn in self._rr if tn in heads]
        if len(order) == 1:
            return heads[order[0]]
        quantum = max(heads[tn].cost for tn in order)
        while True:
            for tn in order:
                if self._deficits.get(tn, 0.0) >= heads[tn].cost:
                    self._rr.remove(tn)
                    self._rr.append(tn)
                    return heads[tn]
            for tn in order:
                w = self.admission.account(tn).weight
                self._deficits[tn] = (self._deficits.get(tn, 0.0)
                                      + quantum * max(w, 1e-9))

    def _charge_fair(self, group: list[Ticket]) -> None:
        """Debit each served ticket's cost from its tenant's deficit.

        Riders batched under another tenant's turn are charged too (they
        got real work), but the debt is floored at one ticket deep —
        unbounded negative deficit would starve a tenant for many
        rotations after one lucky shared batch."""
        for t in group:
            if t.degradation == "timeout":
                continue                 # no solve ran for this ticket
            tn = t.request.tenant
            d = self._deficits.get(tn, 0.0)
            self._deficits[tn] = max(d - t.cost, -t.cost)

    def _expire_split(self, group: list[Ticket]) -> list[Ticket]:
        """Timeout the expired members of a group; returns the live rest.

        Deadline semantics are identical to ``_run_single``'s check, but
        applied per member before a *batched* solve so one stale ticket
        neither blocks nor rides the batch."""
        live = []
        for t in group:
            req = t.request
            age = self._clock() - t.submitted_at
            if req.deadline_s is not None and age > req.deadline_s:
                t.status = "failed"
                t.degradation = "timeout"
                t.error = (f"DeadlineExceeded: deadline of "
                           f"{req.deadline_s}s expired before the solve "
                           f"started (queued {age:.3f}s)")
            else:
                live.append(t)
        return live

    def _advance_warming(self) -> list[Ticket]:
        """Nothing is runnable: step the first blocked pool's deferred
        admission pass, then time out blocked tickets whose deadline
        expired while warming — served from the partially warmed cache's
        stochastic rung when the request carried its own target, failed
        as ``timeout`` otherwise."""
        blocked = [t for t in self._queue if not self._runnable(t)]
        self.registry.step_warm(blocked[0].request.pool_id,
                                max_chunks=self.warm_chunks)
        out: list[Ticket] = []
        for t in list(self._queue):
            if self._runnable(t):
                continue
            req = t.request
            age = self._clock() - t.submitted_at
            if req.deadline_s is None or age <= req.deadline_s:
                continue
            self._queue.remove(t)
            entry = self.registry.peek(req.pool_id)
            res = None
            if (self.degrade and req.target is not None
                    and req.strategy == "gradmatch"
                    and entry is not None and entry.cache is not None):
                res = stochastic_fallback(
                    entry.cache, jnp.asarray(req.target, jnp.float32),
                    req.k, seed=req.seed, lam=req.lam, eps=req.eps,
                    positive=req.positive)
            if res is not None:
                t.result = SelectionResult(
                    res.indices, _normalize(res.weights, res.mask),
                    res.mask, res.err)
                self._served(t, "stochastic")
            else:
                t.status = "failed"
                t.degradation = "timeout"
                t.error = (f"DeadlineExceeded: deadline of "
                           f"{req.deadline_s}s expired while the pool "
                           f"was still warming (queued {age:.3f}s)")
            out.append(t)
        return out

    def _take_group_by_pool(self, pool_id: str) -> list[Ticket]:
        group = [t for t in self._queue if t.request.pool_id == pool_id]
        taken = set(id(t) for t in group)
        self._queue = [t for t in self._queue if id(t) not in taken]
        return group

    def _take_group(self, head: Ticket) -> list[Ticket]:
        # Anchored on the fairness winner: the head always rides its own
        # batch; other same-key tickets (any priority/tenant) fill the
        # remaining slots in queue order — riding is free capacity.
        key = head.request.batch_key()
        group = [head] + [t for t in self._queue if t is not head
                          and t.request.batch_key() == key]
        group = group[: self.max_batch]
        taken = set(id(t) for t in group)
        self._queue = [t for t in self._queue if id(t) not in taken]
        return group

    def _take_share_group(self, head: Ticket) -> list[Ticket]:
        """Brownout grouping: same pool and solve parameters, default
        target/valid, *any* k — the group shares one anytime session and
        each member's answer is the first-k prefix.  Anchored on the
        fairness winner like ``_take_group``."""
        req = head.request

        def shares(t: Ticket) -> bool:
            r = t.request
            return (r.pool_id == req.pool_id and r.strategy == "gradmatch"
                    and r.target is None and r.valid is None
                    and float(r.lam) == float(req.lam)
                    and float(r.eps) == float(req.eps)
                    and r.positive == req.positive)

        group = [head] + [t for t in self._queue
                          if t is not head and shares(t)]
        group = group[: self.max_batch]
        taken = set(id(t) for t in group)
        self._queue = [t for t in self._queue if id(t) not in taken]
        return group

    def _run_shared_anytime(self, entry: PoolEntry,
                            group: list[Ticket]) -> None:
        """One anytime session answers the whole differing-k group.

        The deepest request runs the real incremental solve (rung
        ``"certified"`` — its indices are exactly the one-shot k_max
        solve's); every shallower request is answered as the session's
        first-k prefix, which the full-block prefix-growth schedule
        certifies index-identical to its own one-shot solve
        (``"prefix-shared"``: weights are renormalized, approximate).  A
        live session already covering k_max short-circuits the solve
        entirely.  The state is parked in the session store afterwards so
        the next brownout group (and the degradation ladder) reuse it.
        """
        breaker = self.breakers.get(entry.pool_id)
        req0 = group[0].request
        k_max = max(t.request.k for t in group)
        b = len(group)
        if self.session_lookup is not None:
            reuse = [self.session_lookup(entry.pool_id, entry.fingerprint,
                                         t.request.k) for t in group]
            if all(r is not None for r in reuse):
                for t, res in zip(group, reuse):
                    t.result = res
                    self._served(t, "prefix-shared", batched=b)
                return
        try:
            state = omp_session_start(
                entry.grads, entry.target_sum, k_max, lam=req0.lam,
                eps=req0.eps, positive=req0.positive, valid=entry.valid)
        except Exception as exc:          # fail the group, not the queue
            for t in group:
                t.status = "failed"
                t.error = f"{type(exc).__name__}: {exc}"
            if self._is_pool_fault(exc):
                breaker.record_failure()
            return
        for t in group:
            k = t.request.k
            if k == state.k:
                idx, w, mask, err = session_result(state)
                t.result = SelectionResult(idx, _normalize(w, mask),
                                           mask, err)
                t.status = "done"
                t.batched_with = b
                t.degradation = "certified"
            else:
                idx, w, mask, err = session_prefix_result(state, k)
                t.result = SelectionResult(idx, _normalize(w, mask),
                                           mask, err)
                self._served(t, "prefix-shared", batched=b)
        breaker.record_success()
        self.shared_solves += 1
        if self.session_save is not None:
            self.session_save(entry.pool_id, entry.fingerprint, state)

    def _run_brownout_single(self, entry: PoolEntry,
                             ticket: Ticket) -> None:
        """Full-overload floor for non-interactive gradmatch: a seeded
        subsample solve (stochastic rung) instead of the full pool scan.
        Falls back to the ordinary certified path when no subsample
        arena exists (empty valid set, cache-less chunked pool)."""
        req = ticket.request
        target = (entry.target_sum if req.target is None
                  else jnp.asarray(req.target, jnp.float32))
        res = None
        try:
            if entry.kind == "array":
                valid = entry.valid
                if req.valid is not None:
                    v = jnp.asarray(req.valid, bool)
                    valid = v if valid is None else (valid & v)
                res = stochastic_pool_select(
                    entry.grads, target, req.k, seed=req.seed,
                    lam=req.lam, eps=req.eps, positive=req.positive,
                    valid=valid)
            elif entry.cache is not None and req.valid is None:
                res = stochastic_fallback(
                    entry.cache, target, req.k, seed=req.seed,
                    lam=req.lam, eps=req.eps, positive=req.positive)
        except Exception:
            res = None
        if res is None:
            self._run_single(entry, ticket)
            return
        ticket.result = SelectionResult(
            res.indices, _normalize(res.weights, res.mask), res.mask,
            res.err)
        self._served(ticket, "stochastic")
        self.singles_run += 1

    def _run_gradmatch_batch(self, entry: PoolEntry,
                             group: list[Ticket]) -> None:
        req0 = group[0].request
        b = len(group)
        try:
            # Operand assembly inside the guard too: a malformed
            # per-request target/valid (submit() does not shape-check
            # them) must fail the group, not escape drain().
            targets = jnp.stack([
                entry.target_sum if t.request.target is None
                else jnp.asarray(t.request.target, jnp.float32)
                for t in group])
            base_valid = (entry.valid if entry.valid is not None
                          else jnp.ones((entry.n,), bool))
            valids = jnp.stack([
                base_valid if t.request.valid is None
                else base_valid & jnp.asarray(t.request.valid, bool)
                for t in group])
            # Pad to the power-of-two bucket so the jit cache stays
            # bounded; pad rows re-solve request 0 and are dropped below.
            bb = min(_bucket_b(b), self.max_batch)
            if bb > b:
                pad = bb - b
                targets = jnp.concatenate(
                    [targets, jnp.broadcast_to(targets[0], (pad,) +
                                               targets.shape[1:])])
                valids = jnp.concatenate(
                    [valids, jnp.broadcast_to(valids[0], (pad,) +
                                              valids.shape[1:])])
            idx, w, mask, err = omp_select_batched(
                entry.grads, targets, k=req0.k, lam=req0.lam, eps=req0.eps,
                positive=req0.positive, valid=valids)
        except Exception as exc:          # fail the group, not the queue
            for t in group:
                t.status = "failed"
                t.error = f"{type(exc).__name__}: {exc}"
            return
        for i, t in enumerate(group):
            t.result = SelectionResult(idx[i], _normalize(w[i], mask[i]),
                                       mask[i], err[i])
            t.status = "done"
            t.batched_with = b
            t.degradation = "certified"
        self.breakers.get(entry.pool_id).record_success()
        self.batches_run += 1

    @staticmethod
    def _is_pool_fault(exc: BaseException) -> bool:
        """Failures that indict the *pool* (count toward its breaker), as
        opposed to a caller's malformed request: injected/real I-O faults
        that exhausted retries, stream death, pass-budget blowups."""
        return isinstance(exc, (FaultError,
                                stream_lib.StreamingPassBudgetError))

    def _run_single(self, entry: PoolEntry, ticket: Ticket) -> None:
        req = ticket.request
        breaker = self.breakers.get(entry.pool_id)
        try:
            age = self._clock() - ticket.submitted_at
            if req.deadline_s is not None and age > req.deadline_s:
                ticket.degradation = "timeout"
                raise DeadlineExceeded(
                    f"deadline of {req.deadline_s}s expired before the "
                    f"solve started (queued {age:.3f}s)")
            ticket.result = self._execute_single(entry, req)
            ticket.status = "done"
            ticket.batched_with = 1
            ticket.degradation = "certified"
            breaker.record_success()
        except DeadlineExceeded as exc:
            # Not a pool fault: the pool never got to run.
            ticket.status = "failed"
            ticket.error = f"{type(exc).__name__}: {exc}"
        except Exception as exc:          # surface, don't wedge the queue
            if self._is_pool_fault(exc):
                breaker.record_failure()
                if (self.degrade and req.strategy == "gradmatch"
                        and entry.kind == "chunked"
                        and self._degrade_chunked(entry, ticket, breaker)):
                    self.singles_run += 1
                    return
            ticket.status = "failed"
            ticket.degradation = "failed"
            ticket.error = f"{type(exc).__name__}: {exc}"
        self.singles_run += 1

    def _degrade_chunked(self, entry: PoolEntry, ticket: Ticket,
                         breaker) -> bool:
        """Walk the degradation ladder for a chunked gradmatch solve whose
        certified attempt died on a pool fault.  Returns True when a rung
        produced an answer (labelled on the ticket); the winning rung is
        counted in ``degraded_served``."""
        req = ticket.request
        target = (entry.target_sum if req.target is None
                  else jnp.asarray(req.target, jnp.float32))
        # Rung 2: re-run the certified solve, resuming from the failed
        # attempt's mid-solve checkpoint.  Still bit-identical to
        # fault-free when it completes — the label records that recovery
        # (not the first attempt) produced it.
        if self.checkpoint_root is not None:
            try:
                ticket.result = self._execute_single(entry, req)
            except Exception as exc2:
                if self._is_pool_fault(exc2):
                    breaker.record_failure()
            else:
                self._served(ticket, "resumed")
                breaker.record_success()
                return True
        # Rung 3: first-k prefix of a live anytime session over the same
        # pool content (indices certified by the prefix property).
        if self.session_lookup is not None:
            res = self.session_lookup(entry.pool_id, entry.fingerprint,
                                      req.k)
            if res is not None:
                ticket.result = res
                self._served(ticket, "anytime-prefix")
                return True
        # Rung 4: seeded stochastic-greedy over the rows still resident in
        # the pool's compressed cache — approximate, loader-free.
        res = stochastic_fallback(entry.cache, target, req.k,
                                  seed=req.seed, lam=req.lam, eps=req.eps,
                                  positive=req.positive)
        if res is not None:
            ticket.result = SelectionResult(
                res.indices, _normalize(res.weights, res.mask), res.mask,
                res.err)
            self._served(ticket, "stochastic")
            return True
        return False

    def _served(self, ticket: Ticket, rung: str, batched: int = 1) -> None:
        ticket.status = "done"
        ticket.batched_with = batched
        ticket.degradation = rung
        self.degraded_served[rung] = self.degraded_served.get(rung, 0) + 1

    def _execute_single(self, entry: PoolEntry,
                        req: SelectRequest) -> SelectionResult:
        if req.strategy == "random":
            valid = entry.valid
            if req.valid is not None:
                v = jnp.asarray(req.valid, bool)
                valid = v if valid is None else (valid & v)
            return random_sel.random_select(
                jax.random.PRNGKey(req.seed), entry.n, req.k, valid=valid)
        if req.strategy == "gradmatch" and entry.kind == "chunked":
            if req.valid is not None:
                # The chunk factory was frozen at registration; silently
                # selecting masked rows would be worse than refusing.
                raise ValueError(
                    "per-request valid masks are not supported on chunked "
                    "pools — register the pool with the mask instead")
            target = (entry.target_sum if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            # The admission-warmed compressed cache + row fetcher make
            # this request's certified rounds and repairs hit memory
            # instead of re-paying loader passes (DESIGN.md §7).
            return stream_lib.gradmatch_streaming(
                entry.chunk_iter, req.k, target=target, lam=req.lam,
                eps=req.eps, buffer_size=self.stream_buffer,
                cache=entry.cache, row_fetch=entry.row_fetch,
                retry=self.retry,
                checkpoint_dir=self._checkpoint_dir(entry, req, target),
                checkpoint_every=self.checkpoint_every)
        if req.strategy == "gradmatch-partitioned":
            # Partition-and-merge (core/partition.py, DESIGN.md §9): the
            # pool's registered partition count (0 = solver auto) shapes
            # the split; chunked pools stream contiguous row ranges
            # through the certified engine, resident pools solve hashed
            # partitions device-parallel.
            target = (None if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            if entry.kind == "chunked":
                if req.valid is not None:
                    raise ValueError(
                        "per-request valid masks are not supported on "
                        "chunked pools — register the pool with the mask "
                        "instead")
                return part_lib.gradmatch_partitioned_stream(
                    pool_iter=entry.chunk_iter, k=req.k, n=entry.n,
                    partitions=entry.partitions, row_fetch=entry.row_fetch,
                    target=target, lam=req.lam, eps=req.eps,
                    buffer_size=self.stream_buffer, retry=self.retry)
            valid = entry.valid
            if req.valid is not None:
                v = jnp.asarray(req.valid, bool)
                valid = v if valid is None else (valid & v)
            return part_lib.gradmatch_partitioned(
                entry.grads, req.k, partitions=entry.partitions,
                target=target, lam=req.lam, eps=req.eps, valid=valid)
        if entry.kind != "array":
            raise ValueError(
                f"strategy {req.strategy!r} needs a resident pool")
        valid = entry.valid
        if req.valid is not None:
            v = jnp.asarray(req.valid, bool)
            valid = v if valid is None else (valid & v)
        if req.strategy in _CRAIG_METHODS:
            sim, lm, otf = entry.fl_scan(_CRAIG_METHODS[req.strategy])
            return craig_lib.craig(
                entry.grads, req.k, sim=sim, valid=valid,
                method=_CRAIG_METHODS[req.strategy], l_max=lm,
                on_the_fly=otf, key=jax.random.PRNGKey(req.seed))
        if req.strategy == "glister":
            target = (entry.target_sum if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            return glister_lib.glister(entry.grads, target, req.k,
                                       valid=valid)
        raise ValueError(f"unservable strategy {req.strategy!r}")

    def _checkpoint_dir(self, entry: PoolEntry,
                        req: SelectRequest, target) -> Optional[str]:
        """Per-*solve* checkpoint directory under ``checkpoint_root``.

        The solver refuses to resume a checkpoint from an incompatible
        solve, but the target vector is not part of its compatibility
        check — so the directory key hashes everything that defines the
        solve (pool content, k, lam/eps/positive, target bytes).  Two
        different asks never share a directory.
        """
        if self.checkpoint_root is None:
            return None
        h = hashlib.sha1(repr(
            (entry.fingerprint, req.k, float(req.lam), float(req.eps),
             req.positive)).encode())
        h.update(np.asarray(target, np.float32).tobytes())
        return os.path.join(self.checkpoint_root,
                            f"{entry.pool_id}-{h.hexdigest()[:12]}")

    def stats(self) -> dict:
        return {"pending": len(self._queue),
                "batches_run": self.batches_run,
                "singles_run": self.singles_run,
                "shared_solves": self.shared_solves,
                "counters": dict(self.counters),
                "degraded_served": dict(self.degraded_served),
                "overload": (None if self.overload is None
                             else self.overload.stats()),
                "breakers": self.breakers.stats()}
