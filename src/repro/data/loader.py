"""Weighted-subset mini-batch loader (Algorithm 1 line 9 feeding).

Serves shuffled mini-batches drawn from the current selection
``(indices, weights)`` over a host-resident dataset.  Iteration state
(epoch, cursor, rng key) is an explicit NamedTuple so checkpoints capture
the exact mid-epoch position — restart is bit-exact.

Weights: per the theory (Thm 1 normalization), selection weights sum to 1
over the subset.  A mini-batch of size B re-normalizes its slice to sum to
1 so every SGD step sees the same objective scale regardless of which slice
of the subset it drew (the trainer multiplies by nothing further).
"""

from __future__ import annotations

import os
from typing import Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class LoaderState(NamedTuple):
    epoch: jax.Array     # () int32
    cursor: jax.Array    # () int32 — position within the current permutation
    key: jax.Array       # PRNG key for the *next* permutation


def _check_memmap(arr, name: str) -> None:
    """Refuse a memmap whose backing file is shorter than its claimed view.

    A truncated backing file (partial copy, interrupted download, wrong
    dtype/shape at open) fails *late* otherwise — as a SIGBUS or zeros in
    the tail chunks of a streaming pass, which the corruption detector
    would then quarantine row by row.  Catching the size mismatch at pool
    construction turns that into one early, descriptive error.
    """
    if not isinstance(arr, np.memmap):
        return
    filename = getattr(arr, "filename", None)
    if filename is None:
        return
    need = int(getattr(arr, "offset", 0)) + arr.nbytes
    have = os.path.getsize(filename)
    if have < need:
        raise ValueError(
            f"memmap-backed {name} is truncated: {filename!r} holds "
            f"{have} bytes but shape {arr.shape} / dtype {arr.dtype} at "
            f"offset {int(getattr(arr, 'offset', 0))} needs {need} — the "
            "backing file is incomplete (partial copy?) or the "
            "shape/dtype used to open it is wrong")


class ChunkedPool:
    """Fixed-size, re-iterable chunk view over a host-resident dataset.

    Feeds the streaming selection path (``core/streaming.py``): the pool is
    read one ``chunk_size`` slice at a time in a deterministic order, and
    every ``chunks()`` call restarts from offset 0 — streaming OMP rescans
    the pool when its certification bound fails.  ``x``/``y`` may be
    ``np.memmap`` (or any sliceable array), so an out-of-core pool is never
    materialized in host or device memory.
    """

    def __init__(self, x, y=None, chunk_size: int = 4096):
        _check_memmap(x, "x")
        if y is not None:
            _check_memmap(y, "y")
        self.x = x
        self.y = y
        self.chunk_size = int(chunk_size)

    @property
    def n(self) -> int:
        return self.x.shape[0]

    def num_chunks(self) -> int:
        return -(-self.n // self.chunk_size)

    def chunks(self) -> Iterator[tuple]:
        """Yields ``(x_chunk, y_chunk, offset)``; ``y_chunk`` None if no y."""
        for lo in range(0, self.n, self.chunk_size):
            hi = min(lo + self.chunk_size, self.n)
            yield (self.x[lo:hi],
                   None if self.y is None else self.y[lo:hi], lo)

    def __iter__(self) -> Iterator[tuple]:
        return self.chunks()


class SubsetLoader:
    """Mini-batches over the selected subset with weights.

    The selection is padded/masked (static shapes); invalid slots are
    filtered host-side once per ``set_selection`` — selection cadence is
    every R epochs, so this never touches the step path.
    """

    def __init__(self, x: jax.Array, y: jax.Array, batch_size: int,
                 seed: int = 0):
        self.x = x
        self.y = y
        self.batch_size = batch_size
        self._sel_idx = np.arange(x.shape[0])
        self._sel_w = np.full((x.shape[0],), 1.0 / x.shape[0], np.float32)
        self.state = LoaderState(jnp.int32(0), jnp.int32(0),
                                 jax.random.PRNGKey(seed))

    # -- selection plumbing --------------------------------------------------
    def set_selection(self, indices, weights, mask) -> None:
        idx = np.asarray(indices)
        w = np.asarray(weights, np.float32)
        m = np.asarray(mask, bool) & (idx >= 0)
        self._sel_idx = idx[m]
        self._sel_w = w[m]
        s = self._sel_w.sum()
        self._sel_w = (self._sel_w / s if s > 0 else
                       np.full_like(self._sel_w, 1.0 / max(len(self._sel_w),
                                                           1)))

    @property
    def subset_size(self) -> int:
        return len(self._sel_idx)

    def steps_per_epoch(self) -> int:
        return max(self.subset_size // self.batch_size, 1)

    # -- iteration -----------------------------------------------------------
    def _perm(self, key: jax.Array) -> np.ndarray:
        return np.asarray(jax.random.permutation(key, self.subset_size))

    def next_batch(self) -> dict:
        """One weighted mini-batch; advances (and wraps) the state."""
        n = self.subset_size
        bs = min(self.batch_size, n)
        cur = int(self.state.cursor)
        perm = self._perm(self.state.key)
        if cur + bs > n:  # wrap: new epoch, fresh permutation
            key = jax.random.fold_in(self.state.key, 1)
            self.state = LoaderState(self.state.epoch + 1, jnp.int32(0), key)
            perm = self._perm(key)
            cur = 0
        take = perm[cur: cur + bs]
        self.state = LoaderState(self.state.epoch,
                                 jnp.int32(cur + bs), self.state.key)
        rows = self._sel_idx[take]
        w = self._sel_w[take]
        s = w.sum()
        w = w / s if s > 0 else np.full_like(w, 1.0 / bs)
        return {
            "x": self.x[rows],
            "y": self.y[rows],
            "weights": jnp.asarray(w),
        }

    def epoch_batches(self) -> Iterator[dict]:
        for _ in range(self.steps_per_epoch()):
            yield self.next_batch()

    # -- checkpointing ---------------------------------------------------------
    def checkpoint_state(self) -> dict:
        return {
            "epoch": np.asarray(self.state.epoch),
            "cursor": np.asarray(self.state.cursor),
            "key": np.asarray(self.state.key),
            "sel_idx": self._sel_idx,
            "sel_w": self._sel_w,
        }

    def restore_state(self, st: dict) -> None:
        self.state = LoaderState(jnp.int32(st["epoch"]),
                                 jnp.int32(st["cursor"]),
                                 jnp.asarray(st["key"], jnp.uint32))
        self._sel_idx = np.asarray(st["sel_idx"])
        self._sel_w = np.asarray(st["sel_w"], np.float32)
