"""Structured synthetic classification data (gaussian mixtures).

Each class is a mixture of ``modes_per_class`` gaussians in ``dim``
dimensions with means drawn on a sphere of radius ``sep`` — controllably
(non-)separable and, crucially for this paper, with *class-clustered
gradients*: per-example last-layer gradients of examples in the same class
cluster in gradient space exactly the way CIFAR classes do, which is the
structure GRAD-MATCH / CRAIG exploit.

``make_imbalanced`` replicates the paper's robustness protocol (§5): drop
90% of the examples from 30% of the classes; a clean balanced validation set
is returned for the ``isValid=True`` (validation-gradient-matching) runs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Dataset(NamedTuple):
    x: jax.Array          # (n, dim) f32
    y: jax.Array          # (n,) int32
    num_classes: int

    @property
    def n(self) -> int:
        return self.x.shape[0]


def make_classification(
    key: jax.Array,
    n: int = 4096,
    dim: int = 64,
    num_classes: int = 10,
    modes_per_class: int = 3,
    sep: float = 4.0,
    noise: float = 1.0,
) -> Dataset:
    kmu, kmode, kx, ky = jax.random.split(key, 4)
    means = sep * jax.random.normal(
        kmu, (num_classes, modes_per_class, dim)) / jnp.sqrt(dim)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    mode = jax.random.randint(kmode, (n,), 0, modes_per_class)
    mu = means[y, mode]                                   # (n, dim)
    x = mu + noise * jax.random.normal(kx, (n, dim))
    return Dataset(x.astype(jnp.float32), y.astype(jnp.int32), num_classes)


def split(ds: Dataset, key: jax.Array, val_frac: float = 0.1
          ) -> tuple[Dataset, Dataset]:
    """Deterministic shuffled train/val split (the paper's 90/10)."""
    perm = jax.random.permutation(key, ds.n)
    n_val = int(ds.n * val_frac)
    vi, ti = perm[:n_val], perm[n_val:]
    return (Dataset(ds.x[ti], ds.y[ti], ds.num_classes),
            Dataset(ds.x[vi], ds.y[vi], ds.num_classes))


def make_imbalanced(
    key: jax.Array,
    n: int = 4096,
    dim: int = 64,
    num_classes: int = 10,
    imbalanced_frac: float = 0.3,
    keep_frac: float = 0.1,
    **kw,
) -> tuple[Dataset, Dataset]:
    """Paper §5 class-imbalance protocol.

    Returns (imbalanced_train, clean_val).  ``imbalanced_frac`` of the
    classes keep only ``keep_frac`` of their examples (paper: 30% of classes
    reduced by 90%).  The validation set stays balanced/clean.
    """
    kd, ks, kr = jax.random.split(key, 3)
    full = make_classification(kd, n=n, dim=dim, num_classes=num_classes,
                               **kw)
    train, val = split(full, ks)
    n_imb = int(num_classes * imbalanced_frac)
    imb_classes = jnp.arange(n_imb)        # deterministic: first classes
    is_imb = jnp.isin(train.y, imb_classes)
    u = jax.random.uniform(kr, (train.n,))
    keep = ~is_imb | (u < keep_frac)
    idx = jnp.where(keep, size=train.n, fill_value=-1)[0]
    n_keep = int(jnp.sum(keep))
    idx = idx[:n_keep]
    return (Dataset(train.x[idx], train.y[idx], num_classes), val)
