"""Stateless-indexed LM token stream: sharded + restartable by construction.

Batch ``step`` for data-parallel shard ``shard`` is a pure function of
``(seed, step, shard)`` — no iterator state to checkpoint beyond the step
integer, no cross-host coordination, identical batches on restart from any
step.  This is the standard production arrangement for deterministic
fault-tolerant input pipelines (cf. grain/SeqIO index-based sampling), built
here from ``jax.random.fold_in``.

Token distribution: Zipf-ish unigram marginals mixed with a first-order
Markov kernel over a small latent state, so there IS learnable structure
(perplexity drops under training — the examples rely on that), while
generation stays O(batch * seq) with no host round trips.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def _zipf_logits(vocab: int, alpha: float = 1.1) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def token_batch(
    seed: int | jax.Array,
    step: int | jax.Array,
    shard: int | jax.Array,
    batch: int,
    seq_len: int,
    vocab: int,
    n_latent: int = 16,
    alpha: float = 1.1,
) -> dict:
    """One (batch, seq_len+1) slice -> {'tokens', 'targets'} int32.

    Markov structure: each sequence carries a latent state path (persistent
    chain over ``n_latent`` states); each latent state biases a different
    contiguous slice of the Zipf vocabulary.  Cheap, deterministic,
    learnable.
    """
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed)
                           if not isinstance(seed, jax.Array) else seed,
                           step), shard)
    klat, ktok, kstay = jax.random.split(key, 3)

    # Latent state path: sticky Markov chain via cummax-of-resets trick.
    stay = jax.random.uniform(kstay, (batch, seq_len + 1)) < 0.95
    fresh = jax.random.randint(klat, (batch, seq_len + 1), 0, n_latent)

    def chain(carry, inp):
        s, f = inp
        lat = jnp.where(s, carry, f)
        return lat, lat

    lat0 = fresh[:, 0]
    _, lats = jax.lax.scan(chain, lat0,
                           (stay[:, 1:].T, fresh[:, 1:].T))
    latent = jnp.concatenate([lat0[:, None], lats.T], axis=1)  # (B, S+1)

    # Per-latent vocabulary bias: latent l boosts slice [l*v/L, (l+1)*v/L).
    base = _zipf_logits(vocab, alpha)                          # (V,)
    slice_w = vocab // n_latent
    tok_ids = jnp.arange(vocab)
    in_slice = (tok_ids[None, :] // jnp.maximum(slice_w, 1)
                ) == jnp.arange(n_latent)[:, None]             # (L, V)
    logits = base[None, :] + 3.0 * in_slice.astype(jnp.float32)  # (L, V)

    toks = jax.random.categorical(ktok, logits[latent], axis=-1)  # (B, S+1)
    toks = toks.astype(jnp.int32)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class TokenStream(NamedTuple):
    """Config record for a sharded token pipeline; all methods pure."""

    seed: int
    batch_per_shard: int
    seq_len: int
    vocab: int
    n_shards: int = 1

    def batch(self, step: int, shard: int = 0) -> dict:
        return token_batch(self.seed, step, shard, self.batch_per_shard,
                           self.seq_len, self.vocab)

    def global_batch(self, step: int) -> dict:
        """All shards concatenated — host-side convenience for tests."""
        parts = [self.batch(step, s) for s in range(self.n_shards)]
        return {k: jnp.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}

    def state(self, step: int) -> dict:
        """Checkpointable pipeline state: literally the step index."""
        return {"step": step, "seed": self.seed}

    @staticmethod
    def resume(state: dict) -> int:
        return int(state["step"])
