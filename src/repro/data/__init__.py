"""Deterministic synthetic data substrate (no datasets ship offline).

Two families:

  - ``synthetic``: structured gaussian-mixture classification with optional
    class imbalance (the paper's MNIST/CIFAR stand-in — what matters to
    GRAD-MATCH is class structure in gradient space, which mixtures provide).
  - ``tokens``: a Zipf-distributed, Markov-structured LM token stream,
    *stateless-indexed*: batch ``i`` of shard ``s`` is a pure function of
    ``(seed, i, s)``, so the pipeline is sharded and restartable by
    construction (checkpoint = one integer).

``loader.SubsetLoader`` serves weighted mini-batches from a selected subset
(X^t, w^t) with checkpointable iteration state.
"""

from repro.data.loader import ChunkedPool, LoaderState, SubsetLoader
from repro.data.synthetic import make_classification, make_imbalanced
from repro.data.tokens import TokenStream, token_batch

__all__ = [
    "ChunkedPool",
    "LoaderState",
    "SubsetLoader",
    "TokenStream",
    "make_classification",
    "make_imbalanced",
    "token_batch",
]
