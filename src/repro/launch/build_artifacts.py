"""Offline selection-artifact pipeline (DESIGN.md §12).

Precomputes durable anytime-OMP trajectories for a set of pools and
commits them to a content-addressed ``ArtifactStore`` next to the BENCH
files — the MILO-style "solve once, serve any k" fast path.  A serving
deployment pointed at the same store root
(``SelectionService(artifact_store=...)``) then answers gradmatch
requests for these pools at any ``k <= k_max`` in O(1) at submit, rung
``"artifact"``.

Key congruence matters: the artifact is keyed by the pool's
*full-content* digest and the byte-exact SHA of the default target the
registry computes at admission.  The pipeline therefore registers each
pool through a real ``PoolRegistry`` and builds from the registered
entry's ``content_digest``/``target_sum`` — guaranteeing the serving
path's lookup key matches, including the f32 reduction that produced
the target.

``--smoke`` (the CI configuration) builds small pools, then self-checks
the differential guarantee — every artifact slice index-identical to a
live ``omp_select`` at 3 budgets, weights bit-exact to the anytime
session engine — and exits non-zero on violation.

Run:  PYTHONPATH=src python -m repro.launch.build_artifacts --smoke
      PYTHONPATH=src python -m repro.launch.build_artifacts \
          --pools 4 --pool-size 8192 --dim 64 --k-max 512
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.artifacts import ArtifactStore, build_artifact
from repro.core.omp import omp_select, omp_session_start
from repro.serve.registry import PoolRegistry

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_STORE = REPO_ROOT / "ARTIFACTS_selection"


def build_pools(store: ArtifactStore, pools, k_max: int, lam: float = 0.5,
                eps: float = 1e-10, positive: bool = True) -> list[dict]:
    """Register each (n, d) pool, solve to ``k_max``, commit; returns one
    report dict per pool (ident, build seconds, dims)."""
    registry = PoolRegistry(max_pools=max(len(pools), 1),
                            artifacts=store)
    reports = []
    for g in pools:
        pid = registry.register(g)
        entry = registry.get(pid)
        target = np.asarray(entry.target_sum, np.float32)
        t0 = time.perf_counter()
        key, ident = build_artifact(
            store, np.asarray(g, np.float32), target, k_max, lam=lam,
            eps=eps, positive=positive,
            fingerprint=entry.content_digest)
        dt = time.perf_counter() - t0
        reports.append({"pool_id": pid, "ident": ident, "n": entry.n,
                        "d": entry.d, "k_max": int(k_max),
                        "build_s": dt})
        print(f"build_artifacts,pool={pid},ident={ident},n={entry.n},"
              f"d={entry.d},k_max={k_max},build_s={dt:.2f}", flush=True)
    return reports


def _selfcheck(store: ArtifactStore, pools, reports, lam, eps,
               positive) -> bool:
    """Differential guarantee on every built artifact at 3 k-slices."""
    from repro.artifacts import artifact_key_for

    ok = True
    for g, rep in zip(pools, reports):
        g = np.asarray(g, np.float32)
        import jax.numpy as jnp
        target = np.asarray(jnp.sum(jnp.asarray(g), axis=0), np.float32)
        key = artifact_key_for(g, target, lam, eps, positive)
        art = store.get(key)
        if art is None:
            print(f"build_artifacts,selfcheck={rep['ident']},"
                  f"error=unloadable", flush=True)
            ok = False
            continue
        k_max = rep["k_max"]
        for k in sorted({1, k_max // 2, k_max}):
            idx, w, mask, err = art.slice(k)
            li, lw, lm, _ = omp_select(g, target, k, lam=lam, eps=eps,
                                       positive=positive)
            sess = omp_session_start(g, target, k, lam=lam, eps=eps,
                                     positive=positive)
            same = (np.array_equal(idx, np.asarray(li))
                    and np.array_equal(mask, np.asarray(lm))
                    and np.array_equal(w, np.asarray(sess.weights))
                    and np.allclose(w, np.asarray(lw), rtol=1e-4,
                                    atol=1e-5))
            print(f"build_artifacts,selfcheck={rep['ident']},k={k},"
                  f"ok={same}", flush=True)
            ok &= same
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=str(DEFAULT_STORE),
                    help="artifact store root (default: next to BENCH "
                         "files)")
    ap.add_argument("--pools", type=int, default=2)
    ap.add_argument("--pool-size", type=int, default=8192)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k-max", type=int, default=512)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gc", action="store_true",
                    help="mark-then-sweep the store after building")
    ap.add_argument("--smoke", action="store_true",
                    help="small pools + differential self-check (CI)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.pools = min(args.pools, 2)
        args.pool_size = min(args.pool_size, 512)
        args.dim = min(args.dim, 32)
        args.k_max = min(args.k_max, 48)

    rng = np.random.default_rng(args.seed)
    pools = [rng.standard_normal(
        (args.pool_size, args.dim)).astype(np.float32)
        for _ in range(args.pools)]
    store = ArtifactStore(args.store)
    reports = build_pools(store, pools, args.k_max, lam=args.lam)
    ok = True
    if args.smoke:
        ok = _selfcheck(store, pools, reports, args.lam, 1e-10, True)
    if args.gc:
        swept = store.gc()
        print(f"build_artifacts,gc_objects={swept['objects_swept']},"
              f"gc_tmp={swept['tmp_swept']}", flush=True)
    print(f"build_artifacts,store={args.store},"
          f"artifacts={store.stats()['artifacts']},"
          f"{'PASS' if ok else 'FAIL'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
