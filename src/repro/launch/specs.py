"""ShapeDtypeStruct stand-ins + sharding specs for every dry-run cell.

``input_specs(cfg, shape)`` returns the exact pytree of array stand-ins a
step function consumes (weak-type-correct, shardable, **no allocation**);
``batch_shardings`` / ``state_shardings`` assign NamedShardings with the
divisibility-fallback policy of ``distributed.sharding.fit_spec``:

  - batch dim  -> ('pod','data')          [dropped if it does not divide]
  - KV-cache heads -> 'model', falling back to head_dim when the arch has
    fewer KV heads than the model axis (gemma-2b MQA, gemma2-9b kv=8)
  - global_batch=1 long-context cells -> sequence dim over ('pod','data')
    (sequence parallelism for the 500k KV residency)
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import dp_axes, fit_spec
from repro.models import lm as lm_lib

BF16 = jnp.bfloat16


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Input stand-ins
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch pytree of ShapeDtypeStructs for (arch x shape).

    train/prefill: the full (B, S) token batch (audio: frame embeddings,
    vlm: tokens + patch embeddings).  decode: one new token (B, 1) + scalar
    position; the KV/state cache is produced by ``decode_state_specs``.
    """
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    batch: dict[str, Any] = {}
    if kind == "decode":
        tok_shape = (b, 1)
    else:
        tok_shape = (b, s)

    if cfg.family == "audio":
        # Modality frontend is a stub: precomputed frame embeddings.
        batch["embeds"] = _sds((*tok_shape, cfg.d_model), BF16)
    else:
        batch["tokens"] = _sds(tok_shape, jnp.int32)
    if cfg.family == "vlm" and kind != "decode":
        batch["vision"] = _sds((b, cfg.vision.n_tokens, cfg.vision.d_embed),
                               BF16)
    if kind == "train":
        batch["targets"] = _sds(tok_shape, jnp.int32)
        batch["weights"] = _sds((b,), jnp.float32)
    if kind == "decode":
        batch["pos"] = _sds((), jnp.int32)
    return batch


# ---------------------------------------------------------------------------
# Sharding assignment
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch: dict, *, seq_shard: bool = False
                    ) -> dict:
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_for(name: str, sds) -> P:
        if sds.ndim == 0:
            return P()
        if name == "weights":
            return P(dpe)
        if seq_shard and sds.ndim >= 2 and sds.shape[0] == 1:
            # long-context: batch=1, shard the sequence dim instead.
            return P(None, dpe, *([None] * (sds.ndim - 2)))
        return P(dpe, *([None] * (sds.ndim - 1)))

    return {
        k: NamedSharding(mesh, fit_spec(v.shape, spec_for(k, v), mesh))
        for k, v in batch.items()
    }


def _state_leaf_spec(path: str, shape: tuple, mesh: Mesh, *,
                     stacked: bool, seq_shard: bool) -> P:
    """Greedy divisible assignment for one decode-state leaf.

    Layout conventions (models/*):
      KV cache   (B, S, H_kv, hd)      slot_pos (B, S)
      SSM state  (B, H, P, N)          conv state (B, W, d_in)
      mLSTM C    (B, H, qk, v)         mlstm/slstm vectors (B, H*x) / (B, d)
    """
    dims = list(shape[1:]) if stacked else list(shape)
    ndim = len(dims)
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    model = "model"
    msize = mesh.shape[model]
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]

    entries: list = [None] * ndim
    # 1) data axes on the batch dim when divisible; else (long_500k) on the
    #    sequence dim of KV caches.
    if ndim >= 1 and dims[0] % dsize == 0 and dims[0] > 1:
        entries[0] = dpe
    elif seq_shard and ndim >= 2 and dims[1] % dsize == 0:
        entries[1] = dpe
    # 2) model axis on the first remaining dim it divides (heads, then
    #    head_dim / state dims).  Skip the sequence dim of KV caches
    #    (dim 1 for 4-d caches) so decode writes stay local in the common
    #    case; fall back to it if nothing else divides.
    candidates = [i for i in range(ndim - 1, 0, -1)
                  if entries[i] is None]
    candidates = sorted(candidates, key=lambda i: (i == 1, -i))
    for i in candidates:
        if dims[i] % msize == 0 and dims[i] > 1:
            entries[i] = model
            break
    if stacked:
        entries = [None] + entries
    return P(*entries)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_shapes: Any, *,
                    seq_shard: bool = False) -> Any:
    """NamedShardings for a decode-state pytree (of ShapeDtypeStructs)."""

    def one(kp, leaf):
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        stacked = path.startswith("blocks")
        spec = _state_leaf_spec(path, leaf.shape, mesh, stacked=stacked,
                                seq_shard=seq_shard)
        return NamedSharding(mesh, fit_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStruct pytree of the decode state (no allocation)."""
    return jax.eval_shape(
        lambda: lm_lib.init_decode_state(cfg, shape.global_batch,
                                         shape.seq_len))


def param_specs_shapes(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(
        lambda: lm_lib.init_lm(cfg, jax.random.PRNGKey(0)))
