import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices build the production meshes
((16,16) single-pod, (2,16,16) multi-pod); each cell's step function is
jitted with explicit in/out shardings, ``.lower().compile()`` must succeed,
and the compiled artifact yields

  - ``memory_analysis()``   -> bytes-per-device (proves it fits in 16 GB),
  - ``cost_analysis()``     -> HLO FLOPs / bytes for the roofline terms,
  - partitioned-HLO parse   -> collective operand bytes + schedule.

Usage::

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k [--multi-pod] [--microbatches 4] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

``--all`` runs every applicable cell in a fresh subprocess each (compile
state isolation) and writes one JSON per cell under
``benchmarks/artifacts/dryrun/<mesh>/``.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import hints
from repro.distributed.sharding import (logical_rules, param_shardings)
from repro.launch import specs as specs_lib
from repro.launch.hlo_analysis import (collective_bytes,
                                       collective_bytes_weighted,
                                       roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.models import lm as lm_lib
from repro.optim import OptState, sgd
from repro.train.steps import lm_train_step_fn

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "dryrun")


def _repl(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Cell builders: (fn, example_args, in_shardings, out_shardings, donate)
# ---------------------------------------------------------------------------

def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh,
                microbatches: int, fsdp: bool = True):
    opt = sgd(0.01, momentum=0.9, weight_decay=5e-4)
    raw = lm_train_step_fn(cfg, opt, microbatches=microbatches)

    params_sds = specs_lib.param_specs_shapes(cfg)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = specs_lib.input_specs(cfg, shape)

    p_sh = param_shardings(cfg, params_sds, mesh, fsdp=fsdp)
    o_sh = OptState(
        _repl(mesh),
        None if opt_sds.slots is None else param_shardings(
            cfg, opt_sds.slots, mesh, fsdp=fsdp))
    b_sh = specs_lib.batch_shardings(mesh, batch_sds)
    metrics_sh = {"ce": _repl(mesh), "aux": _repl(mesh), "loss": _repl(mesh)}

    return (raw, (params_sds, opt_sds, batch_sds),
            (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh), (0, 1))


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh):
    params_sds = specs_lib.param_specs_shapes(cfg)
    batch_sds = specs_lib.input_specs(cfg, shape)
    p_sh = param_shardings(cfg, params_sds, mesh, fsdp=False)
    b_sh = specs_lib.batch_shardings(mesh, batch_sds)

    if cfg.encoder_only:
        # Encoder "prefill" = full-sequence logits (per-frame units).
        def fn(params, batch):
            h, _, _ = lm_lib.forward(cfg, params, batch.get("tokens"),
                                     embeds=batch.get("embeds"), mode="train")
            return lm_lib._head_out(cfg, params, h)

        out_sds = jax.eval_shape(fn, params_sds, batch_sds)
        out_sh = NamedSharding(mesh, P(("pod", "data") if "pod" in
                                       mesh.axis_names else "data", None,
                                       "model"))
        return fn, (params_sds, batch_sds), (p_sh, b_sh), out_sh, ()

    def fn(params, batch):
        return lm_lib.prefill_step(cfg, params, batch.get("tokens"),
                                   embeds=batch.get("embeds"),
                                   vision=batch.get("vision"))

    logits_sds, states_sds = jax.eval_shape(fn, params_sds, batch_sds)
    dpe = ("pod", "data") if "pod" in mesh.axis_names else "data"
    logits_sh = NamedSharding(mesh, P(dpe, "model"))
    states_sh = specs_lib.state_shardings(cfg, mesh, states_sds)
    return fn, (params_sds, batch_sds), (p_sh, b_sh), (logits_sh, states_sh
                                                       ), ()


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh):
    params_sds = specs_lib.param_specs_shapes(cfg)
    batch_sds = specs_lib.input_specs(cfg, shape)
    states_sds = specs_lib.decode_state_specs(cfg, shape)
    seq_shard = shape.global_batch == 1

    # Weight-gathered decode for archs whose TP-sharded weights alone
    # crowd out the KV cache (llama-90b: 180 GB bf16 / 16-way TP = 11 GB
    # of a 16 GB chip).  Sharding weights over data x model and gathering
    # per layer trades ICI for HBM — the standard throughput-decode
    # arrangement for batch-128 serving.
    tp = mesh.shape["model"]
    params_gib_tp = cfg.param_count() * 2 / tp / 2**30
    fsdp = params_gib_tp > 8.0
    p_sh = param_shardings(cfg, params_sds, mesh, fsdp=fsdp)
    b_sh = specs_lib.batch_shardings(mesh, batch_sds, seq_shard=seq_shard)
    s_sh = specs_lib.state_shardings(cfg, mesh, states_sds,
                                     seq_shard=seq_shard)
    dpe = ("pod", "data") if "pod" in mesh.axis_names else "data"
    logits_sh = NamedSharding(
        mesh, P(dpe if shape.global_batch > 1 else None, "model"))

    def fn(params, states, batch):
        tokens = batch.get("tokens")
        if tokens is None:  # audio decode is skipped upstream; guard anyway
            raise ValueError("decode requires tokens")
        return lm_lib.decode_step(cfg, params, states, tokens, batch["pos"])

    return (fn, (params_sds, states_sds, batch_sds),
            (p_sh, s_sh, b_sh), (logits_sh, s_sh), (1,))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               microbatches: int):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, microbatches)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)


def analytic_memory_gib(cfg: ModelConfig, shape: ShapeConfig, mesh,
                        microbatches: int) -> dict:
    """Coarse per-chip HBM accounting, independent of the CPU backend.

    XLA:CPU lowers every bf16 dot as convert->f32-dot, so the CPU-measured
    temps systematically overstate what a TPU (native-bf16 MXU) allocates.
    This analytic table is the cross-check for the fits-in-16GB verdict;
    the measured numbers are still reported verbatim.
    """
    n_chips = mesh.size
    tp = mesh.shape["model"]
    dp = n_chips // tp
    n_params = cfg.param_count()
    d = {"params_gib": n_params * 2 / 2**30,
         "per_chip": {}}
    pc = d["per_chip"]
    if shape.kind == "train":
        shard = n_chips  # fsdp: model x data
        pc["params"] = n_params * 2 / shard
        pc["momentum"] = n_params * 4 / shard
        pc["grads_f32"] = n_params * 4 / shard
        tokens_chip = shape.tokens // (dp * microbatches)
        # remat superblock carries + one layer's working set + f32 logits
        pc["act_carries"] = cfg.n_superblocks * tokens_chip * cfg.d_model * 2
        pc["logits_f32"] = tokens_chip * cfg.padded_vocab // tp * 4
    else:
        w_shard = n_chips if (n_params * 2 / tp / 2**30) > 8.0 else tp
        pc["params"] = n_params * 2 / w_shard
        # KV caches / recurrent states: states shard over data x model
        # (heads or head_dim fallback), i.e. ~n_chips-way.
        state_bytes = 0
        for kind in cfg.layer_types_in_order():
            if kind in ("attn", "global", "shared_attn"):
                s_eff = shape.seq_len
            elif kind == "local":
                s_eff = min(cfg.sliding_window or shape.seq_len,
                            shape.seq_len)
            else:   # recurrent: O(1) state per head — negligible
                s_eff = 0
            state_bytes += (2 * shape.global_batch * s_eff
                            * cfg.kv_dim * 2)
        pc["kv_states"] = state_bytes / n_chips
        tokens_chip = max(shape.tokens // dp, shape.seq_len // dp) \
            if shape.kind == "prefill" else shape.global_batch
        pc["activations"] = tokens_chip * cfg.d_model * 2 * 4  # ~4 live
    pc = {k: round(v / 2**30, 3) for k, v in pc.items()}
    d["per_chip"] = pc
    d["per_chip_total_gib"] = round(sum(pc.values()), 2)
    return d


# ---------------------------------------------------------------------------
# One cell: lower + compile + analyse
# ---------------------------------------------------------------------------

def _compile_cell(cfg, shape, mesh, microbatches):
    # The rules context must wrap build_cell too: build_prefill/build_decode
    # run jax.eval_shape over the step fn and jax CACHES that jaxpr — a
    # trace taken outside the context would be reused by .lower() with the
    # hints silently dropped (found the hard way; see EXPERIMENTS §Perf).
    with hints.use_rules(mesh, logical_rules(mesh)):
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh,
                                                     microbatches)
        t0 = time.perf_counter()
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    return {
        "compiled": compiled,
        "lower_s": t_lower, "compile_s": t_compile,
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
        "coll_weighted": collective_bytes_weighted(hlo),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 0) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    if not microbatches:  # adaptive: ~2 sequences per chip per microbatch
        dp = n_chips // mesh.shape["model"]
        microbatches = max(shape.global_batch // (2 * dp), 1)

    # --- pass 1: the PRODUCTION module (scan + remat + microbatching).
    # This is the compile proof + the memory analysis that must fit HBM.
    prod = _compile_cell(cfg, shape, mesh,
                         microbatches if shape.kind == "train" else 1)
    ma = prod["compiled"].memory_analysis()

    # --- pass 2+3: cost accounting.  XLA's cost_analysis counts while-loop
    # bodies ONCE regardless of trip count, so the scanned module's numbers
    # are depth-independent.  Superblocks are homogeneous by construction,
    # so two *unrolled shallow* variants (L=1, L=2) give the exact marginal
    # per-superblock cost; totals extrapolate linearly:
    #     cost(L) = cost(1) + (L-1) * (cost(2) - cost(1)).
    # Residual in-loop work (SSD/mLSTM cross-chunk state carry, sLSTM
    # recurrence) is elementwise-dominated — see DESIGN.md.
    L = cfg.n_superblocks
    # Large flash tiles in the cost modules: same math/FLOPs, far fewer
    # unrolled tile bodies (compile time) — tile size only affects memory,
    # which pass 1 measures.
    cost_cfg = cfg.replace(n_layers=0, unroll_scan=True,
                           flash_block_q=8192, flash_block_kv=8192)
    c1 = _compile_cell(cost_cfg.replace(n_superblocks=1), shape, mesh, 1)
    c2 = _compile_cell(cost_cfg.replace(n_superblocks=2), shape, mesh, 1)

    # Marginal per-superblock deltas are clamped at 0: XLA occasionally
    # hoists/CSEs an op differently between the L=1 and L=2 modules
    # (e.g. zamba2's shared-attention weight gather), which would otherwise
    # produce a negative slope.
    def extrap(key):
        return c1[key] + (L - 1) * max(c2[key] - c1[key], 0.0)

    flops = extrap("flops")
    bytes_accessed = extrap("bytes")
    # Collectives come from the PRODUCTION module with while-loop trip
    # counts applied (hlo_analysis.collective_bytes_weighted): unlike the
    # L1/L2 modules, the production module's GSPMD layout decisions are
    # the ones a real run executes (validated within 7% of a fully
    # unrolled compile for gemma-2b x train_4k).
    wc = prod["coll_weighted"]
    coll_bytes_total = wc.total_bytes
    coll_counts = dict(wc.counts)
    coll_op_bytes = dict(wc.operand_bytes)
    t_lower, t_compile = prod["lower_s"], prod["compile_s"]
    terms = roofline_terms(flops, bytes_accessed, coll_bytes_total, n_chips)

    # MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D inference.
    tokens = (shape.tokens if shape.kind != "decode"
              else shape.global_batch)  # decode: one token per sequence
    per_tok = cfg.flops_per_token()
    model_flops = per_tok * tokens * (1.0 if shape.kind == "train"
                                      else 1.0 / 3.0)
    hlo_flops_global = flops * n_chips
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "microbatches": (microbatches if
                                             shape.kind == "train" else 1),
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            # XLA:CPU ignores buffer donation (alias=0); on TPU the donated
            # params/opt/caches alias in-place, so the honest per-device
            # peak is max(args, outputs) + temps.
            "peak_device_bytes": (max(ma.argument_size_in_bytes,
                                      ma.output_size_in_bytes)
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            "analytic": analytic_memory_gib(cfg, shape, mesh, microbatches),
        },
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collectives": {"counts": coll_counts,
                        "operand_bytes": coll_op_bytes,
                        "total_bytes": coll_bytes_total,
                        "production_module_once_counted":
                            prod["coll"].as_dict()},
        "roofline": terms,
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else None),
        "params_total": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cell_out_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh_dir = "2x16x16" if multi_pod else "16x16"
    d = os.path.join(ARTIFACT_DIR, mesh_dir)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS
        failures = []
        for arch in ARCH_IDS:
            for shape in applicable_shapes(get_config(arch)):
                out = _cell_out_path(arch, shape.name, args.multi_pod)
                if args.skip_existing and os.path.exists(out):
                    with open(out) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {arch} x {shape.name}")
                            continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape.name,
                       "--microbatches", str(args.microbatches),
                       "--out", out]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                print(f"[run ] {arch} x {shape.name} "
                      f"({'2x16x16' if args.multi_pod else '16x16'})",
                      flush=True)
                r = subprocess.run(cmd, env={**os.environ,
                                             "PYTHONPATH": "src"})
                if r.returncode != 0:
                    failures.append((arch, shape.name))
        print(f"\n{'FAILURES: ' + str(failures) if failures else 'all ok'}")
        sys.exit(1 if failures else 0)

    try:
        result = run_cell(args.arch, args.shape, args.multi_pod,
                          args.microbatches)
    except Exception:
        result = {"arch": args.arch, "shape": args.shape,
                  "mesh": "2x16x16" if args.multi_pod else "16x16",
                  "ok": False, "error": traceback.format_exc()}
    out = args.out or _cell_out_path(args.arch, args.shape, args.multi_pod)
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    if result["ok"]:
        m = result["memory"]
        print(f"{args.arch} x {args.shape}: OK  "
              f"peak/device={m['peak_device_bytes']/2**30:.2f} GiB  "
              f"flops/chip={result['hlo_flops_per_chip']:.3g}  "
              f"coll={result['collectives']['total_bytes']/2**30:.3f} GiB  "
              f"dominant={result['roofline']['dominant']}")
    else:
        print(result["error"], file=sys.stderr)
        print(f"{args.arch} x {args.shape}: FAILED", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
