"""Post-SPMD HLO introspection: collective bytes + roofline terms.

``collective_bytes`` parses the *compiled* (partitioned) HLO — collectives
only exist after the SPMD partitioner runs, so ``lowered.as_text()`` (which
still carries shardings as annotations) would miss them.  Per the roofline
spec, we sum **operand** sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute; operand shapes are
resolved from their defining instructions, with the op's own output size as
fallback for operands defined out-of-line (e.g. fusion parameters).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (one-direction per link).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e per-chip constants.
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string
    (handles tuples '(f32[8,2], u32[])')."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)        # op -> count
    operand_bytes: dict = field(default_factory=dict)  # op -> total bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    def as_dict(self) -> dict:
        return {"counts": dict(self.counts),
                "operand_bytes": dict(self.operand_bytes),
                "total_bytes": self.total_bytes}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    # Pass 1: defining sizes for every named instruction.
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # rhs starts with the type, e.g. 'f32[128,64]{1,0} add(...)'.
        sizes[name.lstrip("%")] = _shape_bytes(rhs.split(" ", 1)[0]
                                               if "(" not in rhs.split(" ")[0]
                                               else rhs)
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        op = None
        for c in _COLLECTIVES:
            # op name appears right after the output type; '-start' variants
            # (async) count once, '-done' skipped.
            if re.search(rf"\b{c}(-start)?\(", rhs):
                op = c
                break
            if re.search(rf"\b{c}-done\(", rhs):
                op = "skip"
                break
        if op is None or op == "skip":
            continue
        # Operand list: content of the outermost parens.
        args = rhs[rhs.index("(") + 1: rhs.rindex(")")]
        operands = re.findall(r"%?([\w.\-]+)", args)
        ob = sum(sizes.get(o, 0) for o in operands)
        if ob == 0:  # fallback: output size
            ob = _shape_bytes(rhs.split(" ", 1)[0])
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.operand_bytes[op] = stats.operand_bytes.get(op, 0) + ob
    return stats


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=(%?[\w.\-]+),\s*body=(%?[\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=(%?[\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]],
                                                Optional[str]]:
    """Computation name -> instruction lines.  HLO text: computation
    headers sit at column 0 and end with '{'; instructions are indented."""
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            if line.rstrip().endswith("{"):
                head = line.strip()
                name = head.split()[1] if head.startswith("ENTRY") \
                    else head.split()[0]
                name = name.split("(")[0].lstrip("%").rstrip(",")
                cur = name
                comps[cur] = []
                if head.startswith("ENTRY"):
                    entry = cur
            else:
                cur = None
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def collective_bytes_weighted(hlo_text: str) -> CollectiveStats:
    """Collective operand bytes with while-loop TRIP COUNTS applied.

    XLA's cost_analysis (and a naive HLO walk) counts loop bodies once;
    here every computation's collectives are multiplied by the product of
    enclosing loop trip counts (parsed from the `iter < N` constant in
    each while condition).  This is the honest per-step collective volume
    for scan-based modules — production scans stay compact AND correctly
    accounted.
    """
    comps, entry_name = _split_computations(hlo_text)
    if entry_name is None:
        return collective_bytes(hlo_text)

    # global name -> size map (instruction names are module-unique)
    sizes: dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                name, rhs = m.groups()
                head = rhs.split(" ", 1)[0]
                sizes[name.lstrip("%")] = _shape_bytes(
                    head if "(" not in head else rhs)

    def cond_trips(cond_name: str) -> int:
        consts = [int(c) for lines in [comps.get(cond_name, [])]
                  for line in lines for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    stats = CollectiveStats()
    seen: set[tuple[str, int]] = set()

    def visit(comp_name: str, mult: int):
        if (comp_name, mult) in seen or mult <= 0:
            return
        seen.add((comp_name, mult))
        for line in comps.get(comp_name, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, rhs = m.groups()
            wm = _WHILE_RE.search(rhs)
            if wm:
                trips = cond_trips(wm.group(1).lstrip("%"))
                visit(wm.group(2).lstrip("%"), mult * trips)
                continue
            for cm in _CALL_RE.finditer(rhs):
                visit(cm.group(1).lstrip("%"), mult)
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    args = rhs[rhs.index("(") + 1: rhs.rindex(")")]
                    operands = re.findall(r"%?([\w.\-]+)", args)
                    ob = sum(sizes.get(o, 0) for o in operands)
                    if ob == 0:
                        ob = _shape_bytes(rhs.split(" ", 1)[0])
                    stats.counts[c] = stats.counts.get(c, 0) + mult
                    stats.operand_bytes[c] = (
                        stats.operand_bytes.get(c, 0) + mult * ob)
                    break

    visit(entry_name, 1)
    return stats


def analytic_hbm_bytes(cfg, shape, n_chips: int, tp: int,
                       microbatches: int, fsdp_decode: bool = False
                       ) -> float:
    """First-order per-chip HBM traffic per step (TPU accounting).

    XLA:CPU's ``bytes accessed`` counts unfused op-level traffic (the CPU
    backend barely fuses and adds f32 upcasts of every bf16 dot operand),
    which overstates TPU HBM traffic by 5-20x.  This model counts what a
    fused TPU execution streams:

      train:   params read (fwd+bwd, per microbatch under FSDP-regather),
               grad write/read (f32), momentum r/w (f32), param write,
               activation carries + per-layer working set (r+w), f32
               logits+CE traffic.
      prefill: params read + activation working set + KV write.
      decode:  params read + FULL KV/state read + one token's activations
               (the classic decode bound).
    """
    dp = n_chips // tp
    p_local = cfg.param_count() * 2 / n_chips  # bf16, fsdp layout
    kind = shape.kind
    if kind == "train":
        # FSDP: every microbatch re-reads the gathered weights.
        w_reads = 2 * microbatches * cfg.param_count() * 2 / n_chips
        opt = cfg.param_count() * (4 * 2 + 4 + 2) / n_chips  # m rw, g, p
        tokens_chip = shape.tokens / dp
        act = tokens_chip * cfg.d_model * 2 * (
            cfg.n_superblocks * 2        # remat carries w+r
            + len(cfg.layer_pattern) * cfg.n_superblocks * 8)  # layer ws
        logits = tokens_chip * cfg.padded_vocab / tp * (4 + 4)
        return w_reads + opt + act + logits
    if kind == "prefill":
        w = cfg.param_count() * 2 / tp
        tokens_chip = shape.tokens / dp
        act = tokens_chip * cfg.d_model * 2 * (
            len(cfg.layer_pattern) * cfg.n_superblocks * 6)
        kv_write = 2 * tokens_chip * cfg.kv_dim * 2 * sum(
            1 for k in cfg.layer_types_in_order()
            if k in ("attn", "local", "global", "shared_attn", "xattn"))
        return w + act + kv_write
    # decode: weights + entire KV/state residency, once per token
    w_shard = n_chips if fsdp_decode else tp
    w = cfg.param_count(active_only=True) * 2 / w_shard \
        + (cfg.param_count() - cfg.param_count(active_only=True)) * 2 \
        / (shape.global_batch * 64) / w_shard * 0  # routed experts: touched
    # MoE decode touches only routed experts per token; approximate with
    # active params + routers.
    kv = 0
    for k in cfg.layer_types_in_order():
        if k in ("attn", "global", "shared_attn"):
            s_eff = shape.seq_len
        elif k == "local":
            s_eff = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
        else:
            s_eff = 0
        kv += 2 * shape.global_batch * s_eff * cfg.kv_dim * 2
    return w + kv / n_chips


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, n_chips: int) -> dict:
    """The three roofline terms, in seconds.

    cost_analysis() FLOPs/bytes are per-partition (the compiled module IS
    one partition), so the per-chip terms divide by nothing further; we
    report both per-chip and aggregate-normalized views and the dominant
    term.
    """
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll_bytes / ICI_BW
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_collective), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dom,
        "n_chips": n_chips,
    }
