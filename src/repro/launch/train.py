"""Distributed LM training driver with GRAD-MATCH subset selection.

``--arch <id>`` selects any assigned architecture (smoke-reduced with
``--smoke`` for CPU runs; the full configs are exercised via dryrun.py).
The loop is the production arrangement scaled down:

  - mesh from ``--mesh-data/--mesh-model`` over local devices,
  - params/optimizer sharded by ``distributed.sharding`` (FSDP optional),
  - stateless-indexed token pipeline (restartable by construction),
  - GRAD-MATCHPB candidate selection every R *steps* over a candidate
    window of W upcoming batches: proxies from ``lm.selection_proxy``
    (closed-form head gradient, no trunk backprop), sharded OMP from
    ``core.distributed``, selected micro-batches trained with weights,
  - async checkpointing (+ auto-resume), elastic re-shard on device-count
    change via ``launch/elastic.py``.

Example::

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 100 --select-every 20 --budget 0.25
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import distributed as dist_lib
from repro.core import gradmatch as gm_lib
from repro.data.tokens import TokenStream
from repro.distributed import hints
from repro.distributed.sharding import logical_rules, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import lm as lm_lib
from repro.optim import OptState, cosine_with_warmup, sgd
from repro.train.steps import lm_train_step_fn, make_lm_proxy_step


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8,
                    help="candidate micro-batches per selection window")
    ap.add_argument("--micro-batch", type=int, default=4,
                    help="sequences per micro-batch")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--budget", type=float, default=0.25,
                    help="fraction of candidate micro-batches to train on")
    ap.add_argument("--select-every", type=int, default=20, help="R (steps)")
    ap.add_argument("--window", type=int, default=16,
                    help="candidate window: micro-batches per selection")
    ap.add_argument("--strategy", default="gradmatch-pb",
                    choices=["gradmatch-pb", "random", "full"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--checkpoint-dir")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lam", type=float, default=0.5)
    return ap


def main(argv=None) -> dict:
    args = build_argparser().parse_args(argv)
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)

    key = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm(cfg, key)
    p_sh = param_shardings(cfg, params, mesh, fsdp=args.fsdp)
    params = jax.device_put(params, p_sh)

    opt = sgd(cosine_with_warmup(args.lr, 10, args.steps), momentum=0.9)
    opt_state = opt.init(params)

    step_fn = jax.jit(lm_train_step_fn(cfg, opt), donate_argnums=(0, 1))
    proxy_fn = make_lm_proxy_step(cfg)

    stream = TokenStream(seed=args.seed, batch_per_shard=args.micro_batch,
                         seq_len=args.seq_len, vocab=cfg.vocab_size,
                         n_shards=args.window)
    ckpt = (CheckpointManager(args.checkpoint_dir)
            if args.checkpoint_dir else None)

    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        snap = ckpt.restore()
        from repro.launch.elastic import reshard_like
        params = reshard_like(snap["params"], p_sh)
        opt_state = OptState(
            jnp.asarray(snap["opt_state"]["step"]),
            reshard_like(snap["opt_state"]["slots"],
                         jax.tree_util.tree_map(lambda l: l.sharding,
                                                opt_state.slots)))
        start_step = int(snap["meta"]["step"])
        print(f"[resume] from step {start_step}")

    # Current selection over the candidate window (micro-batch granularity).
    k_batches = max(int(args.window * args.budget), 1)
    sel_batches = np.arange(k_batches)
    sel_weights = np.full((k_batches,), 1.0 / k_batches, np.float32)

    losses = []
    t0 = time.perf_counter()
    sel_seconds = 0.0
    window_round = start_step // args.select_every

    for step in range(start_step, args.steps):
        # --- selection round: pick micro-batches from the upcoming window --
        if args.strategy != "full" and step % args.select_every == 0:
            window_round = step // args.select_every
            ts = time.perf_counter()
            cands = [stream.batch(window_round, s)
                     for s in range(args.window)]
            proxies = jnp.stack([
                jnp.mean(proxy_fn(params, c), axis=0) for c in cands])
            if args.strategy == "gradmatch-pb":
                sel = dist_lib.sharded_omp_select(
                    mesh, proxies, jnp.sum(proxies, axis=0), k_batches,
                    axis="data", lam=args.lam) if mesh.shape["data"] > 1 \
                    and args.window % mesh.shape["data"] == 0 else \
                    gm_lib.gradmatch(proxies, k_batches, lam=args.lam)
                m = np.asarray(sel.mask)
                sel_batches = np.asarray(sel.indices)[m]
                sel_weights = np.asarray(sel.weights)[m]
            else:  # random
                rng = np.random.default_rng(args.seed + step)
                sel_batches = rng.choice(args.window, k_batches,
                                         replace=False)
                sel_weights = np.full((k_batches,), 1.0 / k_batches,
                                      np.float32)
            sel_seconds += time.perf_counter() - ts

        # --- one weighted step on one selected micro-batch -----------------
        pick = step % len(sel_batches)
        batch = dict(stream.batch(window_round, int(sel_batches[pick])))
        w = jnp.full((args.micro_batch,),
                     1.0 / args.micro_batch, jnp.float32)
        batch["weights"] = w * (sel_weights[pick] * len(sel_batches))
        with hints.use_rules(mesh, logical_rules(mesh)):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))

        if ckpt is not None and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, {
                "params": params,
                "opt_state": {"step": opt_state.step,
                              "slots": opt_state.slots},
                "meta": {"step": step + 1, **stream.state(step + 1)},
            })

    if ckpt is not None:
        ckpt.wait()
    wall = time.perf_counter() - t0
    report = {
        "arch": args.arch, "strategy": args.strategy,
        "loss_first": float(np.mean(losses[:5])),
        "loss_last": float(np.mean(losses[-5:])),
        "steps": args.steps, "wall_s": round(wall, 2),
        "selection_s": round(sel_seconds, 2),
    }
    print(report)
    return report


if __name__ == "__main__":
    main()
