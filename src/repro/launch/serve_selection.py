"""Selection-service driver: queued multi-tenant selection over shared pools.

The selection twin of ``launch/serve.py`` (decode serving): a
``SelectionService`` is stood up, synthetic proxy pools are registered,
a queue of ``SelectRequest``s from several tenants is admitted and
drained — same-pool requests micro-batch into one batched OMP solve —
and one client runs an anytime budget extension ``k -> k'``.

``--smoke`` (the CI parity-gate configuration) self-checks the two
correctness claims the service makes and exits non-zero on violation:

* every batched result is index-identical to a direct per-request
  ``omp_select`` over the same pool/target;
* the ``k -> k'`` session continuation is index-identical to a one-shot
  ``k'`` solve.

Run:  PYTHONPATH=src python -m repro.launch.serve_selection --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omp import omp_select
from repro.serve import SelectionService


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools + differential self-checks (CI gate)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pools", type=int, default=2)
    ap.add_argument("--pool-size", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--k-extend", type=int, default=192,
                    help="anytime extension budget (> --k)")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.pool_size = min(args.pool_size, 1024)
        args.k = min(args.k, 64)
        args.k_extend = min(args.k_extend, 96)

    svc = SelectionService(max_batch=args.max_batch,
                          max_queue=max(args.requests * 2, 16))
    rng = np.random.default_rng(args.seed)
    pools = []
    for p in range(args.pools):
        g = rng.standard_normal(
            (args.pool_size, args.dim)).astype(np.float32)
        pools.append((svc.register_pool(g), g))

    # Queue: round-robin tenants over round-robin pools, then one drain —
    # requests sharing a pool land in the same micro-batch.
    t0 = time.perf_counter()
    tickets = []
    for i in range(args.requests):
        pid, _ = pools[i % len(pools)]
        tickets.append(svc.submit(pid, k=args.k,
                                  tenant=f"tenant-{i % args.tenants}"))
    done = svc.drain()
    serve_wall = time.perf_counter() - t0

    failures = []
    if any(t.status != "done" for t in done):
        failures.append("request-failed")
    batch_sizes = sorted({t.batched_with for t in done})

    batched_ok = True
    if args.smoke:
        for t in done:
            g = dict(pools)[t.request.pool_id]
            gj = jnp.asarray(g)
            idx, _, mask, _ = omp_select(gj, jnp.sum(gj, axis=0), k=args.k)
            same = (np.array_equal(np.asarray(t.result.indices),
                                   np.asarray(idx))
                    and np.array_equal(np.asarray(t.result.mask),
                                       np.asarray(mask)))
            batched_ok &= same
        if not batched_ok:
            failures.append("batched-vs-sequential")

    # Anytime budget extension on pool 0: k -> k'.
    pid0, g0 = pools[0]
    t0 = time.perf_counter()
    sid, _ = svc.open_session(pid0, k=args.k, tenant="tenant-0")
    ext = svc.extend_session(sid, args.k_extend)
    extend_wall = time.perf_counter() - t0
    g0j = jnp.asarray(g0)
    one_idx, _, one_mask, _ = omp_select(g0j, jnp.sum(g0j, axis=0),
                                         k=args.k_extend)
    extension_ok = (np.array_equal(np.asarray(ext.indices),
                                   np.asarray(one_idx))
                    and np.array_equal(np.asarray(ext.mask),
                                       np.asarray(one_mask)))
    if not extension_ok:
        failures.append("extension-vs-oneshot")

    stats = svc.stats()
    report = {
        "requests": len(done),
        "pools": args.pools,
        "k": args.k,
        "k_extend": args.k_extend,
        "batch_sizes": batch_sizes,
        "batches_run": stats["scheduler"]["batches_run"],
        "serve_wall_s": round(serve_wall, 3),
        "extend_wall_s": round(extend_wall, 3),
        "batched_ok": batched_ok,
        "extension_ok": extension_ok,
        "failures": failures,
        "ok": not failures,
    }
    print(report)
    return report


if __name__ == "__main__":
    raise SystemExit(0 if main()["ok"] else 1)
