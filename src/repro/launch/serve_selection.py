"""Selection-service driver: queued multi-tenant selection over shared pools.

The selection twin of ``launch/serve.py`` (decode serving): a
``SelectionService`` is stood up, synthetic proxy pools are registered,
a queue of ``SelectRequest``s from several tenants is admitted and
drained — same-pool requests micro-batch into one batched OMP solve —
and one client runs an anytime budget extension ``k -> k'``.

``--smoke`` (the CI parity-gate configuration) self-checks the two
correctness claims the service makes and exits non-zero on violation:

* every batched result is index-identical to a direct per-request
  ``omp_select`` over the same pool/target;
* the ``k -> k'`` session continuation is index-identical to a one-shot
  ``k'`` solve.

``--load`` switches the driver to the open-loop overload scenario
(DESIGN.md §10): seeded Poisson arrivals from two tenants with unequal
offered load and weights, a priority mix, and one fault-injected chunked
pool, driven on a virtual clock through the overload-aware scheduler.
It prints per-tenant p99, the degradation-rung distribution, the
weighted fairness ratio and the shed/refund accounting, and exits
non-zero if any accounting invariant is violated.

Run:  PYTHONPATH=src python -m repro.launch.serve_selection --smoke
      PYTHONPATH=src python -m repro.launch.serve_selection --load
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omp import omp_select
from repro.serve import SelectionService


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools + differential self-checks (CI gate)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--pools", type=int, default=2)
    ap.add_argument("--pool-size", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--k-extend", type=int, default=192,
                    help="anytime extension budget (> --k)")
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load", action="store_true",
                    help="open-loop overload scenario (DESIGN.md §10)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in req/s for --load "
                         "(0 = one saturating burst)")
    ap.add_argument("--fault-rate", type=float, default=0.15,
                    help="transient fault rate on the chunked pool "
                         "(--load)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.pool_size = min(args.pool_size, 1024)
        args.k = min(args.k, 64)
        args.k_extend = min(args.k_extend, 96)
    if args.load:
        return _run_load(args)

    svc = SelectionService(max_batch=args.max_batch,
                          max_queue=max(args.requests * 2, 16))
    rng = np.random.default_rng(args.seed)
    pools = []
    for p in range(args.pools):
        g = rng.standard_normal(
            (args.pool_size, args.dim)).astype(np.float32)
        pools.append((svc.register_pool(g), g))

    # Queue: round-robin tenants over round-robin pools, then one drain —
    # requests sharing a pool land in the same micro-batch.
    t0 = time.perf_counter()
    tickets = []
    for i in range(args.requests):
        pid, _ = pools[i % len(pools)]
        tickets.append(svc.submit(pid, k=args.k,
                                  tenant=f"tenant-{i % args.tenants}"))
    done = svc.drain()
    serve_wall = time.perf_counter() - t0

    failures = []
    if any(t.status != "done" for t in done):
        failures.append("request-failed")
    batch_sizes = sorted({t.batched_with for t in done})

    batched_ok = True
    if args.smoke:
        for t in done:
            g = dict(pools)[t.request.pool_id]
            gj = jnp.asarray(g)
            idx, _, mask, _ = omp_select(gj, jnp.sum(gj, axis=0), k=args.k)
            same = (np.array_equal(np.asarray(t.result.indices),
                                   np.asarray(idx))
                    and np.array_equal(np.asarray(t.result.mask),
                                       np.asarray(mask)))
            batched_ok &= same
        if not batched_ok:
            failures.append("batched-vs-sequential")

    # Anytime budget extension on pool 0: k -> k'.
    pid0, g0 = pools[0]
    t0 = time.perf_counter()
    sid, _ = svc.open_session(pid0, k=args.k, tenant="tenant-0")
    ext = svc.extend_session(sid, args.k_extend)
    extend_wall = time.perf_counter() - t0
    g0j = jnp.asarray(g0)
    one_idx, _, one_mask, _ = omp_select(g0j, jnp.sum(g0j, axis=0),
                                         k=args.k_extend)
    extension_ok = (np.array_equal(np.asarray(ext.indices),
                                   np.asarray(one_idx))
                    and np.array_equal(np.asarray(ext.mask),
                                       np.asarray(one_mask)))
    if not extension_ok:
        failures.append("extension-vs-oneshot")

    stats = svc.stats()
    report = {
        "requests": len(done),
        "pools": args.pools,
        "k": args.k,
        "k_extend": args.k_extend,
        "batch_sizes": batch_sizes,
        "batches_run": stats["scheduler"]["batches_run"],
        "serve_wall_s": round(serve_wall, 3),
        "extend_wall_s": round(extend_wall, 3),
        "batched_ok": batched_ok,
        "extension_ok": extension_ok,
        "failures": failures,
        "ok": not failures,
    }
    print(report)
    return report


def _run_load(args) -> dict:
    """Open-loop overload scenario: two tenants with unequal offered
    load and weights, a priority mix, one healthy resident pool and one
    fault-injected chunked pool."""
    from repro.core import streaming as stream_lib
    from repro.data.loader import ChunkedPool
    from repro.resilience import (FaultPlan, FaultyChunkIterator,
                                  RetryPolicy)
    from repro.serve import LoadSpec, SimClock, make_arrivals, run_load

    n = args.pool_size
    requests = max(args.requests, 24) if args.requests == 8 \
        else args.requests
    if args.smoke:
        n, requests = min(n, 1024), min(requests, 16)
    k_small = max(args.k // 2, 4)
    ks = (k_small, args.k)
    retry = RetryPolicy(max_retries=25, backoff_s=0.0,
                        sleep=lambda s: None)
    clock = SimClock()
    svc = SelectionService(
        max_batch=args.max_batch, max_queue=max(2 * requests, 16),
        max_inflight_per_tenant=2 * requests, clock=clock.now,
        retry_policy=retry, brownout_at=0.4, overload_at=0.85,
        recover_at=0.1)
    # team-a: 2/3 of the offered load at weight 2; team-b: 1/3 at
    # weight 1 — unequal load *and* unequal entitlement, so the
    # fairness ratio below is about weighted shares, not raw counts.
    svc.admission.set_weight("team-a", 2.0)
    svc.admission.set_weight("team-b", 1.0)
    rng = np.random.default_rng(args.seed)
    g = rng.standard_normal((n, args.dim)).astype(np.float32)
    g_ch = rng.standard_normal((n, args.dim)).astype(np.float32)
    pid = svc.register_pool(g, pool_id="load-resident")
    faulty = FaultyChunkIterator(
        stream_lib.chunked_pool_iter(ChunkedPool(g_ch,
                                                 chunk_size=max(n // 8,
                                                                64))),
        FaultPlan(transient_rate=args.fault_rate, seed=args.seed))
    pid_ch = svc.register_chunked_pool(faulty, pool_id="load-chunked")
    for k in ks:                                   # jit warm off-trace
        svc.select(pid, k=k)
        svc.select(pid_ch, k=k)
    sid, _ = svc.open_session(pid, k=max(ks))
    svc.close_session(sid)

    spec = LoadSpec(
        seed=args.seed, requests=requests,
        rate_rps=args.rate if args.rate > 0 else 1e6,
        pools=(pid, pid_ch), pool_weights=(3, 1), ks=ks,
        tenants=("team-a", "team-b"), tenant_weights=(2, 1),
        priorities=("interactive", "batch", "best-effort"),
        priority_weights=(5, 3, 2))
    rep = run_load(svc, make_arrivals(spec), clock)

    report = {
        "mode": "load",
        "requests": rep.requests,
        "completed": rep.completed,
        "shed": rep.shed,
        "failed": rep.failed,
        "rejected": rep.rejected,
        "sustained_rps": round(rep.sustained_rps, 2),
        "p50_ms": round(rep.p50_ms, 2),
        "p99_ms": round(rep.p99_ms, 2),
        "tenant_p99_ms": {t: round(v, 2)
                          for t, v in sorted(rep.tenant_p99_ms.items())},
        "rungs": dict(sorted(rep.rungs.items())),
        "fairness_ratio": (None if rep.fairness_ratio is None
                           else round(rep.fairness_ratio, 3)),
        "faults_injected": dict(faulty.injected),
        "overload": svc.scheduler.stats()["overload"],
        "violations": rep.violations,
        "ok": rep.ok and rep.completed > 0,
    }
    print(report)
    return report


if __name__ == "__main__":
    raise SystemExit(0 if main()["ok"] else 1)
