"""Serving driver: batched prefill + decode with a paged-in request queue.

``--arch <id> --smoke`` runs a reduced config end-to-end on CPU: a queue of
synthetic prompts is prefilled in batches, then decoded token-by-token with
a shared KV/state cache (continuous batch of equal-length requests —
slot-level batching; admission happens between decode bursts).

The full-size serving path is exercised (lower+compile only) by
``launch/dryrun.py`` on the production meshes — the decode/prefill step
functions here are the same ones the dry-run lowers.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm as lm_lib


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")

    key = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm(cfg, key)
    s_max = args.prompt_len + args.gen_len

    prefill = jax.jit(lambda p, t: lm_lib.prefill_step(cfg, p, t))
    decode = jax.jit(lambda p, st, t, pos: lm_lib.decode_step(
        cfg, p, st, t, pos))

    # Request queue: synthetic prompts, admitted in fixed-size batches.
    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab_size, (args.prompt_len,))
             for _ in range(args.requests)]

    done = 0
    t0 = time.perf_counter()
    tokens_out = 0
    while queue:
        batch_prompts = [queue.pop(0) for _ in range(
            min(args.batch, len(queue) + 1)) if queue or True][:args.batch]
        while len(batch_prompts) < args.batch:   # pad the last batch
            batch_prompts.append(batch_prompts[-1])
        toks = jnp.asarray(np.stack(batch_prompts), jnp.int32)

        # prefill gives the state at prompt_len; decode state buffers are
        # sized to s_max, so we re-seat the prefill caches into full-size
        # buffers (slot copy) before decoding.
        logits, pstate = prefill(params, toks)
        state = lm_lib.init_decode_state(cfg, args.batch, s_max)
        state = _seat(state, pstate)

        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for i in range(args.gen_len):
            pos = jnp.int32(args.prompt_len + i)
            logits, state = decode(params, state, cur, pos)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            tokens_out += args.batch
        done += args.batch

    wall = time.perf_counter() - t0
    report = {"arch": args.arch, "requests": done,
              "tokens": tokens_out, "wall_s": round(wall, 2),
              "tok_per_s": round(tokens_out / wall, 1)}
    print(report)
    return report


def _seat(full_state, prefill_state):
    """Copy prefill caches into the (larger) decode buffers, leaf-wise.

    Works for flat ((B, S, ...)) and scan-stacked ((L, B, S, ...)) caches:
    the prefix copy happens along the first dim where shapes differ (the
    sequence dim).
    """
    import jax

    def seat(f, p):
        if p.shape == f.shape:
            return p.astype(f.dtype)
        dim = next(i for i, (a, b) in enumerate(zip(f.shape, p.shape))
                   if a != b)
        if p.shape[dim] > f.shape[dim]:
            # windowed prefill caches are padded to the full window; the
            # decode buffer may be smaller (s_max < window): truncate —
            # slots past s_max are empty by construction.
            sl = tuple([slice(None)] * dim + [slice(0, f.shape[dim])]
                       + [slice(None)] * (f.ndim - dim - 1))
            return p[sl].astype(f.dtype)
        sl = tuple([slice(None)] * dim + [slice(0, p.shape[dim])]
                   + [slice(None)] * (f.ndim - dim - 1))
        return f.at[sl].set(p.astype(f.dtype))

    return jax.tree_util.tree_map(seat, full_state, prefill_state)


if __name__ == "__main__":
    main()
