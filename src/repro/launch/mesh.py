"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; tests use
small host meshes).

Axes:
  - ``pod``   (multi-pod only): outermost; composes with ``data`` for
    gradient reduction. Scaling to more pods = growing this axis.
  - ``data``  : data parallel / FSDP axis.
  - ``model`` : tensor/expert parallel axis (Megatron TP, MoE EP, and the
    sequence-parallel KV fallback for the 500k cells).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
