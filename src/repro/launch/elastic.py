"""Elastic scaling: restore a checkpoint onto a different device count.

The checkpoint format (checkpoint/checkpoint.py) is layout-free: plain
host arrays keyed by pytree path.  Re-meshing is therefore just
``device_put`` with the *new* mesh's shardings — no resharding pass, no
all-to-all, works across any (old devices) -> (new devices) transition
including shrink (node loss) and grow (node recovery).

``reshard_like(tree, shardings)`` is the restore half; the save half is
whatever CheckpointManager wrote.  ``rendezvous`` models the control-plane
decision a real cluster makes after a membership change: rebuild the mesh
from the surviving device count and recompute shardings.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed.sharding import param_shardings
from repro.launch.mesh import make_host_mesh


def reshard_like(tree_np: Any, shardings: Any) -> Any:
    """device_put every leaf with its target sharding (pytrees must match).

    Leaves of ``tree_np`` may be numpy (fresh from a checkpoint) or jax
    arrays from a *different* mesh — both paths go through host transfer,
    which is exactly what a post-failure restore does.
    """
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree_np, shardings)


def rendezvous(cfg: ModelConfig, params_np: Any, *, data: int, model: int,
               fsdp: bool = False) -> tuple[Mesh, Any]:
    """Re-mesh onto the current device population and reshard params.

    Returns (new mesh, resharded params).  Call after a membership change
    with the surviving (data, model) split; every other piece of state
    (optimizer slots, selection state) reshards with the same mechanism.
    """
    mesh = make_host_mesh(data, model)
    sh = param_shardings(cfg, params_np, mesh, fsdp=fsdp)
    return mesh, reshard_like(params_np, sh)
