"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent).  [arXiv:2405.04517]

mLSTM per head, in stabilized log-space (the exponential input gate forces a
running max stabilizer ``m`` — unlike SSD whose decays are all <= 1):

    m_t = max(logsig(f_t) + m_{t-1}, i_t)
    C_t = exp(logsig(f_t) + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = (same decay) n_{t-1} + exp(i_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))

The chunkwise form factors every within-chunk coefficient as
``exp((i_s - b_s) - g_t)`` with b = cumsum(logsig(f)), a = cummax(i - b),
g_t = max(m_prev, a_t): all exponents are <= 0, so the (Q, Q) decay matrix is
stable by construction.  Cross-chunk state (C, n, m) is carried by lax.scan.

sLSTM is the paper's strictly-sequential scalar-memory cell (one lax.scan
step per token) with block-diagonal per-head recurrence, followed by the
gated up-projection FFN.  Decode for both is the O(1) single-step recurrence.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dtype_of
from repro.models.ssm import _causal_conv

_M_CLAMP = 60.0  # exp(60) ~ 1e26: safe in f32


def mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    h = cfg.n_heads
    dv = d_in // h
    dk = int(d_in * x.qk_dim_factor) // h
    return d_in, h, dk, dv


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ModelConfig, key: jax.Array) -> dict:
    x = cfg.xlstm
    dt = dtype_of(cfg)
    d_in, h, dk, dv = mlstm_dims(cfg)
    qk = h * dk
    ks = jax.random.split(key, 7)
    return {
        "up_proj": common.dense_init(ks[0], (cfg.d_model, d_in), dt),
        "z_proj": common.dense_init(ks[1], (cfg.d_model, d_in), dt),
        "conv": common.dense_init(ks[2], (x.conv_dim, d_in), dt,
                                  fan_in=x.conv_dim),
        "wq": common.dense_init(ks[3], (d_in, qk), dt, fan_in=d_in),
        "wk": common.dense_init(ks[4], (d_in, qk), dt, fan_in=d_in),
        "wi_gate": common.dense_init(ks[5], (d_in, h), dt, fan_in=d_in),
        "wf_gate": common.dense_init(ks[6], (d_in, h), dt, fan_in=d_in),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # start remembering
        "head_norm": jnp.ones((d_in,), jnp.float32),
        "down_proj": common.dense_init(
            jax.random.fold_in(key, 7), (d_in, cfg.d_model), dt, fan_in=d_in),
    }


def _mlstm_chunk_scan(q, k, v, ig, fg, chunk, state):
    """q/k (B,T,H,dk), v (B,T,H,dv), ig/fg (B,T,H) f32.
    state = (C (B,H,dk,dv), n (B,H,dk), m (B,H)) f32.
    Returns (h (B,T,H,dv) f32, new state)."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    qc = min(chunk, t)
    nc = t // qc
    assert nc * qc == t, f"seq {t} not divisible by chunk {qc}"

    def reshape_c(x):
        return x.reshape(b, nc, qc, *x.shape[2:]).swapaxes(0, 1)

    qs, ks_, vs = reshape_c(q), reshape_c(k), reshape_c(v)
    igs, fgs = reshape_c(ig), reshape_c(fg)

    smask = (jnp.arange(qc)[:, None] >= jnp.arange(qc)[None, :])

    def body(carry, inp):
        c_prev, n_prev, m_prev = carry
        qi, ki, vi, ii, fi = inp                       # (B,Q,H,*) / (B,Q,H)
        logf = jax.nn.log_sigmoid(fi)                  # (B,Q,H)
        bcum = jnp.cumsum(logf, axis=1)
        ib = ii - bcum
        a = lax.cummax(ib, axis=1)
        g = jnp.maximum(m_prev[:, None, :], a)         # (B,Q,H)
        m_t = bcum + g

        carry_coef = jnp.exp(m_prev[:, None, :] - g)   # (B,Q,H) <= 1
        # D[t,s] = exp(ib_s - g_t), s <= t   -> (B,H,Qt,Qs)
        dmat = jnp.exp(
            ib.transpose(0, 2, 1)[:, :, None, :]
            - g.transpose(0, 2, 1)[:, :, :, None]
        )
        dmat = jnp.where(smask[None, None], dmat, 0.0)
        scores = jnp.einsum("bthk,bshk->bhts", qi, ki)
        wmat = scores * dmat

        num = jnp.einsum("bhts,bshd->bthd", wmat, vi)
        num = num + carry_coef[..., None] * jnp.einsum(
            "bthk,bhkd->bthd", qi, c_prev)
        den = jnp.einsum("bhts->bth", wmat)
        den = den + carry_coef * jnp.einsum("bthk,bhk->bth", qi, n_prev)
        floor = jnp.exp(jnp.minimum(-m_t, _M_CLAMP))
        hout = num / jnp.maximum(jnp.abs(den), floor)[..., None]

        g_end = g[:, -1]                               # (B,H)
        u_end = jnp.exp(ib - g_end[:, None, :])        # (B,Q,H) <= 1
        coef = jnp.exp(m_prev - g_end)
        c_new = coef[..., None, None] * c_prev + jnp.einsum(
            "bqh,bqhk,bqhd->bhkd", u_end, ki, vi)
        n_new = coef[..., None] * n_prev + jnp.einsum(
            "bqh,bqhk->bhk", u_end, ki)
        m_new = bcum[:, -1] + g_end
        return (c_new, n_new, m_new), hout

    state_f, hs = lax.scan(body, state, (qs, ks_, vs, igs, fgs))
    h_full = hs.swapaxes(0, 1).reshape(b, t, h, dv)
    return h_full, state_f


def _mlstm_chunkwise_parallel(q, k, v, ig, fg, chunk, state):
    """Chunkwise-*parallel* mLSTM: numerically identical to
    ``_mlstm_chunk_scan`` (tested) but with all heavy einsums OUTSIDE the
    cross-chunk recurrence.

    TPU adaptation (DESIGN.md §4 / §Perf): the serial form runs the
    O(Q²·dk + Q·dk·dv) intra-chunk contractions inside a ``lax.scan`` —
    nc sequential MXU launches and an XLA cost model that counts the body
    once.  Here phase A computes per-chunk summaries for ALL chunks in
    parallel (one big batched einsum), phase B scans only the O(H·dk·dv)
    elementwise state recurrence, and phase C combines intra- and
    inter-chunk contributions in parallel.  Stabilization: all
    exponentials are taken relative to the per-chunk running max ``a`` or
    its sequential refinement ``g`` — every exp() stays <= 1 exactly as in
    the serial form.
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    qc = min(chunk, t)
    nc = t // qc
    assert nc * qc == t, f"seq {t} not divisible by chunk {qc}"

    def rc(x):  # (B,T,...) -> (B,NC,Q,...)
        return x.reshape(b, nc, qc, *x.shape[2:])

    from repro.distributed import hints
    qs, ks_, vs = rc(q), rc(k), rc(v)
    igs, fgs = rc(ig), rc(fg)                      # (B,NC,Q,H)

    # ---- phase A: per-chunk parallel quantities ---------------------------
    logf = jax.nn.log_sigmoid(fgs)
    bcum = jnp.cumsum(logf, axis=2)                # (B,NC,Q,H)
    ib = igs - bcum
    a = lax.cummax(ib, axis=2)                     # running max within chunk
    a_end = a[:, :, -1]                            # (B,NC,H)
    bcum_end = bcum[:, :, -1]

    # Stable chunk summaries relative to a_end (ib <= a_end within chunk).
    u_p = jnp.exp(ib - a_end[:, :, None])          # (B,NC,Q,H) <= 1
    # 'mlstm_chunk_state' hint (no-op without a rule): pins the per-chunk
    # state layout so the summary einsums, the cross-chunk scan and the
    # combine phase agree — without it GSPMD reshards (B,NC,H,dk,dv)
    # between phases every layer (§Roofline: the xlstm train outlier).
    u_c = hints.constrain(
        jnp.einsum("bcqh,bcqhk,bcqhd->bchkd", u_p, ks_, vs),
        "mlstm_chunk_state")
    nu_c = jnp.einsum("bcqh,bcqhk->bchk", u_p, ks_)

    # Intra-chunk attention-like part relative to a_t (row max).
    smask = jnp.arange(qc)[:, None] >= jnp.arange(qc)[None, :]
    dmat_p = jnp.exp(
        ib.transpose(0, 1, 3, 2)[:, :, :, None, :]       # ib_s  (B,NC,H,1,Q)
        - a.transpose(0, 1, 3, 2)[:, :, :, :, None]      # a_t   (B,NC,H,Q,1)
    )
    dmat_p = jnp.where(smask[None, None, None], dmat_p, 0.0)
    scores = jnp.einsum("bcthk,bcshk->bchts", qs, ks_)
    wmat = scores * dmat_p                          # (B,NC,H,Q,Q)
    intra_num = jnp.einsum("bchts,bcshd->bcthd", wmat, vs)
    intra_den = jnp.sum(wmat, axis=-1)              # (B,NC,H,Q)
    intra_den = intra_den.transpose(0, 1, 3, 2)     # (B,NC,Q,H)

    # ---- phase B: cheap cross-chunk state recurrence ----------------------
    def body(carry, inp):
        c_prev, n_prev, m_prev = carry
        ae, be, uc, nuc = inp
        g_end = jnp.maximum(m_prev, ae)             # (B,H)
        coef = jnp.exp(m_prev - g_end)
        su = jnp.exp(ae - g_end)
        c_new = coef[..., None, None] * c_prev + su[..., None, None] * uc
        n_new = coef[..., None] * n_prev + su[..., None] * nuc
        m_new = be + g_end
        return (c_new, n_new, m_new), (c_prev, n_prev, m_prev)

    xs = (jnp.moveaxis(a_end, 1, 0), jnp.moveaxis(bcum_end, 1, 0),
          jnp.moveaxis(u_c, 1, 0), jnp.moveaxis(nu_c, 1, 0))
    state_f, (c_prevs, n_prevs, m_prevs) = lax.scan(body, state, xs)
    c_prevs = hints.constrain(jnp.moveaxis(c_prevs, 0, 1),
                              "mlstm_chunk_state")  # (B,NC,H,dk,dv)
    n_prevs = jnp.moveaxis(n_prevs, 0, 1)           # (B,NC,H,dk)
    m_prevs = jnp.moveaxis(m_prevs, 0, 1)           # (B,NC,H)

    # ---- phase C: parallel combine ----------------------------------------
    g = jnp.maximum(m_prevs[:, :, None], a)         # (B,NC,Q,H)
    m_t = bcum + g
    r = jnp.exp(a - g)                              # row rescale <= 1
    carry_coef = jnp.exp(m_prevs[:, :, None] - g)   # (B,NC,Q,H)
    inter_num = jnp.einsum("bcqhk,bchkd->bcqhd", qs, c_prevs)
    num = r[..., None] * intra_num + carry_coef[..., None] * inter_num
    inter_den = jnp.einsum("bcqhk,bchk->bcqh", qs, n_prevs)
    den = r * intra_den + carry_coef * inter_den
    floor = jnp.exp(jnp.minimum(-m_t, _M_CLAMP))
    hout = num / jnp.maximum(jnp.abs(den), floor)[..., None]
    return hout.reshape(b, t, h, dv), state_f


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    x = cfg.xlstm
    d_in, h, dk, dv = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -_M_CLAMP, jnp.float32),
        "conv": jnp.zeros((batch, x.conv_dim - 1, d_in), dtype_of(cfg)),
    }


def _mlstm_project(cfg, p, x, conv_tail):
    from repro.distributed import hints
    d_in, h, dk, dv = mlstm_dims(cfg)
    b, t, _ = x.shape
    up = x @ p["up_proj"]
    z = x @ p["z_proj"]
    c, tail = _causal_conv(up, p["conv"], conv_tail)
    c = jax.nn.silu(c)
    # 'mlstm_qk' hint (no-op without a rule): with wq/wk TP-sharded on
    # their output dim, the per-chunk score einsums contract over a
    # sharded dk -> an all-reduce per chunk per layer.  Pinning q/k
    # replicated HERE gathers once per layer instead (33 MB vs 16 ARs).
    q = hints.constrain(
        (c @ p["wq"]).reshape(b, t, h, dk), "mlstm_qk").astype(jnp.float32)
    q = q / math.sqrt(dk)
    k = hints.constrain(
        (c @ p["wk"]).reshape(b, t, h, dk), "mlstm_qk").astype(jnp.float32)
    v = up.reshape(b, t, h, dv).astype(jnp.float32)
    ig = (c @ p["wi_gate"]).astype(jnp.float32) + p["b_i"]
    fg = (c @ p["wf_gate"]).astype(jnp.float32) + p["b_f"]
    return up, z, q, k, v, ig, fg, tail


def _head_norm_gate(p, hmat, z, x_dtype):
    """Per-head RMS norm, scale, silu(z) gate."""
    ms = jnp.mean(jnp.square(hmat), axis=-1, keepdims=True)
    hn = hmat * lax.rsqrt(ms + 1e-6)
    b, t = hmat.shape[:2]
    hn = hn.reshape(b, t, -1) * p["head_norm"]
    return (hn * jax.nn.silu(z.astype(jnp.float32))).astype(x_dtype)


def mlstm_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                state: dict | None = None, return_state: bool = False):
    b = x.shape[0]
    st = state or init_mlstm_state(cfg, b)
    up, z, q, k, v, ig, fg, tail = _mlstm_project(cfg, p, x, st["conv"])
    h, (c_new, n_new, m_new) = _mlstm_chunkwise_parallel(
        q, k, v, ig, fg, cfg.xlstm.chunk, (st["C"], st["n"], st["m"]))
    y = _head_norm_gate(p, h, z, x.dtype) @ p["down_proj"]
    if not return_state:
        return y, None
    return y, {"C": c_new, "n": n_new, "m": m_new, "conv": tail}


def mlstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """Single-token recurrence.  x (B,1,d)."""
    up, z, q, k, v, ig, fg, tail = _mlstm_project(cfg, p, x, state["conv"])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]             # (B,H,dk/dv)
    i1, f1 = ig[:, 0], fg[:, 0]                        # (B,H)
    logf = jax.nn.log_sigmoid(f1)
    m_new = jnp.maximum(logf + state["m"], i1)
    coef_f = jnp.exp(logf + state["m"] - m_new)
    coef_i = jnp.exp(i1 - m_new)
    c_new = coef_f[..., None, None] * state["C"] + coef_i[..., None, None] \
        * (k1[..., :, None] * v1[..., None, :])
    n_new = coef_f[..., None] * state["n"] + coef_i[..., None] * k1
    num = jnp.einsum("bhk,bhkd->bhd", q1, c_new)
    den = jnp.einsum("bhk,bhk->bh", q1, n_new)
    floor = jnp.exp(jnp.minimum(-m_new, _M_CLAMP))
    h = (num / jnp.maximum(jnp.abs(den), floor)[..., None])[:, None]
    y = _head_norm_gate(p, h, z, x.dtype) @ p["down_proj"]
    return y, {"C": c_new, "n": n_new, "m": m_new, "conv": tail}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key: jax.Array) -> dict:
    x = cfg.xlstm
    dt = dtype_of(cfg)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ff = int(d * x.slstm_ff_factor)
    ks = jax.random.split(key, 12)
    p = {}
    for n, kk in zip(("z", "i", "f", "o"), ks[:4]):
        p[f"w_{n}"] = common.dense_init(kk, (d, d), dt)
    for n, kk in zip(("z", "i", "f", "o"), ks[4:8]):
        p[f"r_{n}"] = common.dense_init(kk, (h, dh, dh), dt, fan_in=dh)
    p["b_z"] = jnp.zeros((d,), jnp.float32)
    p["b_i"] = jnp.zeros((d,), jnp.float32)
    p["b_f"] = jnp.full((d,), 3.0, jnp.float32)
    p["b_o"] = jnp.zeros((d,), jnp.float32)
    p["head_norm"] = jnp.ones((d,), jnp.float32)
    p["ff_gate"] = common.dense_init(ks[8], (d, ff), dt)
    p["ff_up"] = common.dense_init(ks[9], (d, ff), dt)
    p["ff_down"] = common.dense_init(ks[10], (ff, d), dt, fan_in=ff)
    return p


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.full((batch, d), 1e-6, jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(cfg, p, xz, xi, xf, xo, state):
    """One recurrent step.  x* (B,d) f32 pre-activations from the input side;
    state dict of (B,d) f32.  Returns (h, new_state)."""
    h_heads = state["h"].reshape(-1, cfg.n_heads,
                                 cfg.d_model // cfg.n_heads)

    def rec(w):
        return jnp.einsum("bhd,hde->bhe", h_heads,
                          w.astype(jnp.float32)).reshape(state["h"].shape)

    z = jnp.tanh(xz + rec(p["r_z"]) + p["b_z"])
    i_pre = xi + rec(p["r_i"]) + p["b_i"]
    f_pre = xf + rec(p["r_f"]) + p["b_f"]
    o = jax.nn.sigmoid(xo + rec(p["r_o"]) + p["b_o"])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    coef_f = jnp.exp(logf + state["m"] - m_new)
    coef_i = jnp.exp(i_pre - m_new)
    c_new = coef_f * state["c"] + coef_i * z
    n_new = coef_f * state["n"] + coef_i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def _slstm_ff(cfg, p, h, x_dtype):
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = (h * lax.rsqrt(ms + 1e-6) * p["head_norm"]).astype(x_dtype)
    f = jax.nn.gelu(hn @ p["ff_gate"], approximate=True) * (hn @ p["ff_up"])
    return f @ p["ff_down"]


def slstm_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                state: dict | None = None, return_state: bool = False):
    """Strictly-sequential scan over T.  x (B,T,d)."""
    b, t, d = x.shape
    st = state or init_slstm_state(cfg, b)
    xz = (x @ p["w_z"]).astype(jnp.float32)
    xi = (x @ p["w_i"]).astype(jnp.float32)
    xf = (x @ p["w_f"]).astype(jnp.float32)
    xo = (x @ p["w_o"]).astype(jnp.float32)

    def body(carry, inp):
        h, new = _slstm_cell(cfg, p, *inp, carry)
        return new, h

    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (xz, xi, xf, xo))
    st_new, hs = lax.scan(body, st, xs)
    h_seq = jnp.swapaxes(hs, 0, 1)                     # (B,T,d) f32
    y = _slstm_ff(cfg, p, h_seq, x.dtype)
    return y, (st_new if return_state else None)


def slstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    xz = (x[:, 0] @ p["w_z"]).astype(jnp.float32)
    xi = (x[:, 0] @ p["w_i"]).astype(jnp.float32)
    xf = (x[:, 0] @ p["w_f"]).astype(jnp.float32)
    xo = (x[:, 0] @ p["w_o"]).astype(jnp.float32)
    h, st_new = _slstm_cell(cfg, p, xz, xi, xf, xo, state)
    y = _slstm_ff(cfg, p, h[:, None], x.dtype)
    return y, st_new
