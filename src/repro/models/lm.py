"""LM assembly: config -> init / forward / loss / prefill / decode.

One code path covers all 10 assigned architectures.  The layer stack is a
``lax.scan`` over *super-blocks* (cfg.layer_pattern defines the sub-layers of
one scanned block; heterogeneous archs scan their natural period — see
DESIGN.md §5).  Three modes:

  - ``train``:   stateless forward, optionally remat'd per super-block
  - ``prefill``: forward that also returns the decode state pytree
  - ``decode``:  one token against the state (the ``serve_step``)

The weighted loss is the GRAD-MATCH integration point: ``lm_loss`` takes
per-sequence weights ``w`` (the OMP output, summing to 1) and computes
``sum_i w_i * meanCE_i`` — exactly the weighted-subset objective of paper
Alg. 1 line 9, as a first-class input of the step function.

Zamba2's shared attention block lives OUTSIDE the scan (its weights are
reused at every invocation — the parameter-sharing trick); its per-invocation
KV caches live INSIDE the scanned state (each invocation attends at its own
depth).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN, GLOBAL, LOCAL, MAMBA, MLSTM, SHARED_ATTN,
                                SLSTM, XATTN, ModelConfig)
from repro.distributed import hints
from repro.models import attention, common, ffn, moe, ssm, xlstm
from repro.models.common import dtype_of


# ---------------------------------------------------------------------------
# Sub-layer init / apply
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return kind in (ATTN, LOCAL, GLOBAL, XATTN, SHARED_ATTN)


def _init_ffn_or_moe(cfg: ModelConfig, key: jax.Array, kind: str) -> dict:
    if cfg.uses_moe and kind != XATTN:
        return moe.init_moe(cfg, key)
    return ffn.init_ffn(cfg, key)


def _init_sublayer(cfg: ModelConfig, kind: str, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    if kind in (ATTN, LOCAL, GLOBAL, SHARED_ATTN):
        p = {
            "norm1": common.init_norm(cfg),
            "attn": attention.init_attention(cfg, ks[0]),
            "norm2": common.init_norm(cfg),
            "mlp": _init_ffn_or_moe(cfg, ks[1], kind),
        }
        if cfg.post_norm:
            p["post_norm1"] = common.init_norm(cfg)
            p["post_norm2"] = common.init_norm(cfg)
        return p
    if kind == XATTN:
        return {
            "norm1": common.init_norm(cfg),
            "attn": attention.init_attention(cfg, ks[0], cross=True),
            "norm2": common.init_norm(cfg),
            "mlp": ffn.init_ffn(cfg, ks[1]),
            "gate_attn": jnp.zeros((), jnp.float32),
            "gate_mlp": jnp.zeros((), jnp.float32),
        }
    if kind == MAMBA:
        return {"norm1": common.init_norm(cfg),
                "mixer": ssm.init_mamba(cfg, ks[0])}
    if kind == MLSTM:
        return {"norm1": common.init_norm(cfg),
                "mixer": xlstm.init_mlstm(cfg, ks[0])}
    if kind == SLSTM:
        return {"norm1": common.init_norm(cfg),
                "mixer": xlstm.init_slstm(cfg, ks[0])}
    raise ValueError(kind)


def _init_substate(cfg: ModelConfig, kind: str, batch: int, s_max: int):
    """Decode-state pytree for one sub-layer (zeros; prefill overwrites)."""
    if kind in (ATTN, GLOBAL, SHARED_ATTN):
        return attention.init_decode_cache(cfg, batch, s_max)
    if kind == LOCAL:
        return attention.init_decode_cache(cfg, batch, s_max,
                                           window=cfg.sliding_window)
    if kind == XATTN:
        n_img = cfg.vision.n_tokens
        dt = dtype_of(cfg)
        return {
            "k": jnp.zeros((batch, n_img, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((batch, n_img, cfg.n_kv_heads, cfg.head_dim), dt),
        }
    if kind == MAMBA:
        return ssm.init_state(cfg, batch)
    if kind == MLSTM:
        return xlstm.init_mlstm_state(cfg, batch)
    if kind == SLSTM:
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def _apply_sublayer(cfg: ModelConfig, kind: str, p: dict, x: jax.Array, *,
                    mode: str, positions: Optional[jax.Array] = None,
                    pos: Optional[jax.Array] = None,
                    state: Any = None, vision: Optional[jax.Array] = None):
    """Returns (x_out, new_state, aux_loss)."""
    aux = jnp.float32(0.0)
    want_state = mode == "prefill"

    if kind in (ATTN, LOCAL, GLOBAL, SHARED_ATTN):
        window = cfg.sliding_window if kind == LOCAL else None
        h = common.norm_apply(cfg, p["norm1"], x)
        if mode == "decode":
            a, new_attn_state = attention.decode_self_attention(
                cfg, p["attn"], h, state, pos, window=window)
        else:
            a, new_attn_state = attention.self_attention(
                cfg, p["attn"], h, positions, window=window,
                return_cache=want_state)
        if cfg.post_norm:
            a = common.norm_apply(cfg, p["post_norm1"], a)
        x = x + a
        h = common.norm_apply(cfg, p["norm2"], x)
        if cfg.uses_moe:
            f, aux = moe.moe_apply(cfg, p["mlp"], h,
                                   group="batch" if mode == "decode"
                                   else "seq")
        else:
            f = ffn.ffn_apply(cfg, p["mlp"], h)
        if cfg.post_norm:
            f = common.norm_apply(cfg, p["post_norm2"], f)
        x = x + f
        x = hints.constrain(x, "residual")
        return x, new_attn_state, aux

    if kind == XATTN:
        h = common.norm_apply(cfg, p["norm1"], x)
        if mode == "decode":
            a, _ = attention.cross_attention(cfg, p["attn"], h,
                                             kv_cache=state)
            new_state = state  # vision KV is static during decode
        else:
            a, new_state = attention.cross_attention(
                cfg, p["attn"], h, kv_states=vision,
                return_cache=want_state)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h = common.norm_apply(cfg, p["norm2"], x)
        f = ffn.ffn_apply(cfg, p["mlp"], h)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * f
        x = hints.constrain(x, "residual")
        return x, new_state, aux

    # recurrent mixers (mamba2 / mlstm / slstm)
    h = common.norm_apply(cfg, p["norm1"], x)
    fn = {MAMBA: (ssm.mamba_apply, ssm.mamba_decode),
          MLSTM: (xlstm.mlstm_apply, xlstm.mlstm_decode),
          SLSTM: (xlstm.slstm_apply, xlstm.slstm_decode)}[kind]
    if mode == "decode":
        y, new_state = fn[1](cfg, p["mixer"], h, state)
    else:
        y, new_state = fn[0](cfg, p["mixer"], h, state=state,
                             return_state=want_state)
    x = x + y
    x = hints.constrain(x, "residual")
    return x, new_state, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    params: dict = {}

    if not cfg.encoder_only or cfg.family != "audio":
        params["embed"] = common.embed_init(
            keys[0], (cfg.padded_vocab, cfg.d_model), dt)
    if cfg.encoder_only:
        # hubert head: frame hidden -> unit logits
        params["unit_head"] = common.dense_init(
            keys[1], (cfg.d_model, cfg.padded_vocab), dt)
    elif not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            keys[1], (cfg.d_model, cfg.padded_vocab), dt)

    # Prologue (unscanned) layers.
    if cfg.prologue:
        params["prologue"] = {
            f"pro{i}": _init_sublayer(cfg, kind,
                                      jax.random.fold_in(keys[2], i))
            for i, kind in enumerate(cfg.prologue)
        }

    # Shared block (zamba2): one set of weights, reused per invocation.
    if SHARED_ATTN in cfg.layer_pattern:
        params["shared"] = _init_sublayer(cfg, SHARED_ATTN, keys[3])

    # Scanned super-blocks: stack per-superblock params on a leading axis.
    def one_superblock(k):
        out = {}
        for si, kind in enumerate(cfg.layer_pattern):
            if kind == SHARED_ATTN:
                continue  # weights live in params['shared']
            out[f"sub{si}"] = _init_sublayer(cfg, kind,
                                             jax.random.fold_in(k, si))
        return out

    if cfg.n_superblocks:
        blocks = [one_superblock(jax.random.fold_in(keys[4], i))
                  for i in range(cfg.n_superblocks)]
        params["blocks"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *blocks)

    params["final_norm"] = common.init_norm(cfg)
    return params


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """Full decode-state pytree (scanned states stacked over superblocks)."""
    state: dict = {}
    if cfg.prologue:
        state["prologue"] = {
            f"pro{i}": _init_substate(cfg, kind, batch, s_max)
            for i, kind in enumerate(cfg.prologue)
        }

    def one_superblock():
        return {f"sub{si}": _init_substate(cfg, kind, batch, s_max)
                for si, kind in enumerate(cfg.layer_pattern)}

    if cfg.n_superblocks:
        blocks = [one_superblock() for _ in range(cfg.n_superblocks)]
        state["blocks"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *blocks)
    return state


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_in(cfg: ModelConfig, params: dict, tokens: Optional[jax.Array],
              embeds: Optional[jax.Array]) -> jax.Array:
    if embeds is not None:
        return embeds.astype(dtype_of(cfg))
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head_out(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    h = common.norm_apply(cfg, params["final_norm"], h)
    if cfg.encoder_only:
        logits = h @ params["unit_head"]
    elif cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = common.softcap(logits, cfg.logit_softcap)
    logits = hints.constrain(logits, "logits")
    return logits


def mask_padded_logits(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    v = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(v, logits, jnp.asarray(-1e9, logits.dtype))


def forward(cfg: ModelConfig, params: dict, tokens: Optional[jax.Array] = None,
            *, embeds: Optional[jax.Array] = None,
            vision: Optional[jax.Array] = None,
            mode: str = "train", states: Optional[dict] = None,
            pos: Optional[jax.Array] = None):
    """Trunk forward.  Returns (hidden (B,S,d), new_states, aux_loss)."""
    x = _embed_in(cfg, params, tokens, embeds)
    if vision is not None:
        vision = vision.astype(dtype_of(cfg))
    b, s, _ = x.shape
    if mode == "decode":
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.float32(0.0)
    new_states: dict = {}

    def sub(kind, p, x, st):
        return _apply_sublayer(cfg, kind, p, x, mode=mode,
                               positions=positions, pos=pos, state=st,
                               vision=vision)

    # ---- prologue ----------------------------------------------------------
    if cfg.prologue:
        new_states["prologue"] = {}
        for i, kind in enumerate(cfg.prologue):
            st = states["prologue"][f"pro{i}"] if states else None
            x, nst, a = sub(kind, params["prologue"][f"pro{i}"], x, st)
            aux = aux + a
            if nst is not None:
                new_states["prologue"][f"pro{i}"] = nst

    # ---- scanned super-blocks ---------------------------------------------
    if cfg.n_superblocks:
        shared_p = params.get("shared")

        def body(carry, xs_slice):
            xx, aa = carry
            bp, bst = xs_slice
            out_states = {}
            for si, kind in enumerate(cfg.layer_pattern):
                p = shared_p if kind == SHARED_ATTN else bp[f"sub{si}"]
                st = bst[f"sub{si}"] if bst is not None else None
                xx, nst, a = sub(kind, p, xx, st)
                aa = aa + a
                out_states[f"sub{si}"] = (
                    nst if nst is not None else jnp.zeros((), jnp.float32))
            return (xx, aa), out_states

        body_fn = body
        if cfg.remat and mode == "train":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        unroll = cfg.n_superblocks if cfg.unroll_scan else 1
        bstates = states["blocks"] if states else None
        if bstates is None:
            # feed a None-shaped placeholder via explicit loop over scan xs
            xs = (params["blocks"], None)

            def body_nostate(carry, bp):
                return body_fn(carry, (bp, None))

            (x, aux), ys = lax.scan(body_nostate, (x, aux), params["blocks"],
                                    unroll=unroll)
        else:
            (x, aux), ys = lax.scan(body_fn, (x, aux),
                                    (params["blocks"], bstates),
                                    unroll=unroll)
        if mode == "prefill" or mode == "decode":
            new_states["blocks"] = ys

    return x, new_states, aux


# ---------------------------------------------------------------------------
# Loss (weighted-subset CE: the paper's Alg. 1 line 9 objective)
# ---------------------------------------------------------------------------

def token_ce(cfg: ModelConfig, logits: jax.Array, targets: jax.Array
             ) -> jax.Array:
    """Stable per-token CE in f32.  logits (..., Vpad), targets (...)."""
    lg = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        v = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        lg = jnp.where(v, lg, -1e9)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    own = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return lse - own


def lm_loss(cfg: ModelConfig, params: dict, batch: dict
            ) -> tuple[jax.Array, dict]:
    """Weighted-subset LM/encoder loss.

    batch: tokens (B,S) [or embeds (B,S,d) for audio], targets (B,S),
    optional weights (B,) summing to 1 (defaults to uniform), optional
    loss_mask (B,S), optional vision (B,N,d_vis).
    Returns (loss, metrics).
    """
    h, _, aux = forward(
        cfg, params, batch.get("tokens"), embeds=batch.get("embeds"),
        vision=batch.get("vision"), mode="train")
    logits = _head_out(cfg, params, h)
    ce = token_ce(cfg, logits, batch["targets"])              # (B,S) f32
    mask = batch.get("loss_mask")
    if mask is not None:
        per_seq = jnp.sum(ce * mask, -1) / jnp.maximum(jnp.sum(mask, -1), 1)
    else:
        per_seq = jnp.mean(ce, axis=-1)                       # (B,)
    w = batch.get("weights")
    if w is None:
        w = jnp.full(per_seq.shape, 1.0 / per_seq.shape[0], jnp.float32)
    loss = jnp.sum(w.astype(jnp.float32) * per_seq) + aux
    metrics = {"ce": jnp.mean(per_seq), "aux": aux, "loss": loss}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def prefill_step(cfg: ModelConfig, params: dict, tokens: Optional[jax.Array],
                 *, embeds=None, vision=None):
    """Process the whole prompt; return (last-token logits, decode states)."""
    h, states, _ = forward(cfg, params, tokens, embeds=embeds, vision=vision,
                           mode="prefill")
    logits = _head_out(cfg, params, h[:, -1:])[:, 0]
    logits = mask_padded_logits(cfg, logits)
    return logits, states


def decode_step(cfg: ModelConfig, params: dict, states: dict,
                tokens: jax.Array, pos: jax.Array):
    """One new token (B,1) at absolute position ``pos`` (scalar int32)
    against the decode state.  Returns (logits (B, Vpad), new states)."""
    h, new_states, _ = forward(cfg, params, tokens, mode="decode",
                               states=states, pos=pos)
    logits = _head_out(cfg, params, h)[:, 0]
    logits = mask_padded_logits(cfg, logits)
    return logits, new_states


# ---------------------------------------------------------------------------
# Selection proxies (GRAD-MATCH hook): last-layer gradients for LM heads
# ---------------------------------------------------------------------------

def selection_proxy(cfg: ModelConfig, params: dict, batch: dict
                    ) -> jax.Array:
    """Per-sequence gradient proxy (B, d_model): the exact head-input
    gradient dL/dh mean-pooled over tokens (paper §4 last-layer trick,
    adapted to LM heads — DESIGN.md §3).  No trunk backprop.
    """
    h, _, _ = forward(cfg, params, batch.get("tokens"),
                      embeds=batch.get("embeds"),
                      vision=batch.get("vision"), mode="train")
    logits = _head_out(cfg, params, h)
    if cfg.encoder_only:
        w_head = params["unit_head"]
    elif cfg.tie_embeddings:
        w_head = params["embed"].T
    else:
        w_head = params["lm_head"]
    resid = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    resid = resid - jax.nn.one_hot(batch["targets"], cfg.padded_vocab,
                                   dtype=jnp.float32)
    # dL/dh_t = resid_t @ W^T ; mean over tokens -> one proxy per sequence.
    g = jnp.einsum("bsv,dv->bsd", resid, w_head.astype(jnp.float32))
    return jnp.mean(g, axis=1)
