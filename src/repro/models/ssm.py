"""Mamba2 (SSD — state-space duality) block, chunkwise-parallel.

Training/prefill use the SSD chunked algorithm (Dao & Gu 2024): within a
chunk of Q tokens the output is a masked attention-like contraction; across
chunks a (H, P, N) state is carried by a short ``lax.scan``.  Decode is the
O(1) recurrence  h' = exp(dt*A) h + dt * B (x) outer,  y = C . h' + D x.

All decay math runs in f32; dA = dt * A <= 0 always (A = -exp(A_log),
dt = softplus >= 0), so every exp() in the chunked form is <= 1 — no
stabilizers needed (unlike the xLSTM block, which has exponential *input*
gates and does need them).

Projections are stored separately (x/z/B/C/dt) rather than as one fused
in_proj so each can carry its natural PartitionSpec (d_inner column-parallel
over 'model'; the small B/C/dt heads replicated) — see
distributed/sharding.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dtype_of


def dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.head_dim, s.d_state


def init_mamba(cfg: ModelConfig, key: jax.Array) -> dict:
    s = cfg.ssm
    dt = dtype_of(cfg)
    d_in, nh, _, n = dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "out_proj": common.dense_init(ks[8], (d_in, cfg.d_model), dt,
                                      fan_in=d_in),
        "x_proj": common.dense_init(ks[0], (cfg.d_model, d_in), dt),
        "z_proj": common.dense_init(ks[1], (cfg.d_model, d_in), dt),
        "b_proj": common.dense_init(ks[2], (cfg.d_model, n), dt),
        "c_proj": common.dense_init(ks[3], (cfg.d_model, n), dt),
        "dt_proj": common.dense_init(ks[4], (cfg.d_model, nh), dt),
        "conv_x": common.dense_init(ks[5], (s.d_conv, d_in), dt, fan_in=s.d_conv),
        "conv_b": common.dense_init(ks[6], (s.d_conv, n), dt, fan_in=s.d_conv),
        "conv_c": common.dense_init(ks[7], (s.d_conv, n), dt, fan_in=s.d_conv),
        "a_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "gate_norm": jnp.ones((d_in,), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv.  x (B,T,C), w (W,C); tail (B,W-1,C) carries the
    previous tokens (decode/prefill continuation).  Returns (y, new_tail)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xe = jnp.concatenate([tail, x], axis=1)               # (B, T+W-1, C)
    y = sum(xe[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_tail = xe[:, xe.shape[1] - (width - 1):]
    return y, new_tail


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    """Mamba2's norm-then-gate: rmsnorm(y * silu(z)) * scale."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * lax.rsqrt(ms + 1e-6) * scale).astype(y.dtype)


def _ssd_chunked(xh, dtv, bmat, cmat, a, chunk, state0):
    """SSD scan.  xh (B,T,H,P); dtv (B,T,H) f32; bmat/cmat (B,T,N); a (H,) f32
    negative; state0 (B,H,P,N) f32.  Returns (y (B,T,H,P), state (B,H,P,N))."""
    b, t, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, t)
    nc = t // q
    assert nc * q == t, f"seq {t} not divisible by chunk {q}"

    xc = xh.reshape(b, nc, q, h, p)
    dtc = dtv.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)

    da = dtc * a                                         # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(da, axis=2)                         # within-chunk cumsum
    total = cum[:, :, -1]                                # (B,nc,H)

    # --- intra-chunk (attention-like, causal-masked decay matrix) ---------
    # L[b,c,h,i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, None, :, :,
                                                              None]
    decay = jnp.where(mask, jnp.exp(diff), 0.0)            # (B,nc,Qi,Qj,H)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,nc,Qi,Qj)
    w_intra = scores[..., None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp",
                         w_intra, xc.astype(jnp.float32))

    # --- chunk states ------------------------------------------------------
    dec_out = jnp.exp(total[:, :, None, :] - cum)          # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                         dec_out * dtc, bc, xc.astype(jnp.float32))

    # --- inter-chunk recurrence -------------------------------------------
    def step(s_prev, inp):
        tot_c, s_c = inp                                   # (B,H), (B,H,P,N)
        s_new = jnp.exp(tot_c)[:, :, None, None] * s_prev + s_c
        return s_new, s_prev                               # emit state BEFORE

    tot_t = jnp.moveaxis(total, 1, 0)                      # (nc,B,H)
    sc_t = jnp.moveaxis(s_chunk, 1, 0)                     # (nc,B,H,P,N)
    state_f, s_prevs = lax.scan(step, state0, (tot_t, sc_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                  # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         cc, s_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y, state_f


def init_state(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d_in, nh, p, n = dims(cfg)
    return {
        "ssd": jnp.zeros((batch, nh, p, n), jnp.float32),
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype_of(cfg)),
        "conv_b": jnp.zeros((batch, s.d_conv - 1, n), dtype_of(cfg)),
        "conv_c": jnp.zeros((batch, s.d_conv - 1, n), dtype_of(cfg)),
    }


def mamba_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                state: dict | None = None, return_state: bool = False):
    """Full-sequence Mamba2.  x (B,T,d) -> (y (B,T,d), state | None)."""
    b, t, _ = x.shape
    d_in, nh, hp, n = dims(cfg)
    st = state or init_state(cfg, b)

    xs = x @ p["x_proj"]
    z = x @ p["z_proj"]
    bm = x @ p["b_proj"]
    cm = x @ p["c_proj"]
    dtv = x @ p["dt_proj"]

    xs, tx = _causal_conv(xs, p["conv_x"], st["conv_x"])
    bm, tb = _causal_conv(bm, p["conv_b"], st["conv_b"])
    cm, tc = _causal_conv(cm, p["conv_c"], st["conv_c"])
    xs, bm, cm = jax.nn.silu(xs), jax.nn.silu(bm), jax.nn.silu(cm)

    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(b, t, nh, hp)

    y, s_new = _ssd_chunked(xh, dtv, bm, cm, a, cfg.ssm.chunk, st["ssd"])
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["gate_norm"])
    out = y @ p["out_proj"]
    if not return_state:
        return out, None
    return out, {"ssd": s_new, "conv_x": tx, "conv_b": tb, "conv_c": tc}


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """Single-token recurrent step.  x (B,1,d)."""
    b = x.shape[0]
    d_in, nh, hp, n = dims(cfg)

    xs = x @ p["x_proj"]
    z = x @ p["z_proj"]
    bm = x @ p["b_proj"]
    cm = x @ p["c_proj"]
    dtv = x @ p["dt_proj"]

    xs, tx = _causal_conv(xs, p["conv_x"], state["conv_x"])
    bm, tb = _causal_conv(bm, p["conv_b"], state["conv_b"])
    cm, tc = _causal_conv(cm, p["conv_c"], state["conv_c"])
    xs, bm, cm = jax.nn.silu(xs), jax.nn.silu(bm), jax.nn.silu(cm)

    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"])[:, 0]
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    bmf = bm[:, 0].astype(jnp.float32)
    cmf = cm[:, 0].astype(jnp.float32)

    decay = jnp.exp(dtv * a)                                  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtv, xh, bmf)
    s_new = decay[:, :, None, None] * state["ssd"] + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, cmf)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["gate_norm"])
    out = y @ p["out_proj"]
    return out, {"ssd": s_new, "conv_x": tx, "conv_b": tb, "conv_c": tc}
