"""Pure-JAX functional model zoo (init/apply pairs, dict pytrees).

lm.py assembles the 10 assigned architectures from the layer primitives in
attention/ffn/moe/ssm/xlstm; classifier.py carries the paper's own small
models for the faithful reproduction experiments.
"""

from repro.models import (attention, classifier, common, ffn, lm, moe, ssm,
                          xlstm)  # noqa: F401
