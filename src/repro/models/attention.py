"""Self/cross attention with GQA/MQA, RoPE, sliding window, softcap, KV cache.

One implementation covers all attention flavours in the assigned archs:

  - full causal self-attention          (llama/qwen/starcoder/moonshot)
  - bidirectional encoder attention     (hubert)
  - MQA (n_kv_heads=1)                  (gemma-2b)
  - local/global alternation + softcaps (gemma2-9b)
  - q/k head RMSNorm                    (qwen3-moe)
  - cross-attention to vision states    (llama-3.2-vision)
  - shared-weight attention block       (zamba2; sharing handled by lm.py)

Decode state: ``full`` layers carry a (B, S_max, n_kv, hd) cache written at
the scalar position; ``window`` layers carry a ring buffer of ``window``
slots plus a slot->absolute-position map, so a 500k-context gemma2 local
layer holds only 4096 KV rows.  Cross-attn KV over the (static) vision
states is computed once at prefill and reused every decode step.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import hints
from repro.models import common
from repro.models.common import apply_rope, dtype_of, softcap

MASK_VALUE = -2.0e38


def init_attention(cfg: ModelConfig, key: jax.Array, cross: bool = False
                   ) -> dict:
    dt = dtype_of(cfg)
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    kv_src = cfg.vision.d_embed if (cross and cfg.vision) else cfg.d_model
    p = {
        "wq": common.dense_init(kq, (cfg.d_model, cfg.q_dim), dt),
        "wk": common.dense_init(kk, (kv_src, cfg.kv_dim), dt),
        "wv": common.dense_init(kv, (kv_src, cfg.kv_dim), dt),
        "wo": common.dense_init(ko, (cfg.q_dim, cfg.d_model), dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bo"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def _project_q(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    q = x @ p["wq"]
    if cfg.attn_bias:
        q = q + p["bq"]
    return q.reshape(*x.shape[:-1], cfg.n_heads, cfg.head_dim)


def _project_kv(cfg: ModelConfig, p: dict, x: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        k, v = k + p["bk"], v + p["bv"]
    shape = (*x.shape[:-1], cfg.n_kv_heads, cfg.head_dim)
    return k.reshape(shape), v.reshape(shape)


def _qk_norm(cfg: ModelConfig, p: dict, q: jax.Array, k: jax.Array):
    if cfg.qk_norm:
        q = common.rms_head_norm(q, p["q_norm"])
        k = common.rms_head_norm(k, p["k_norm"])
    return q, k


def _attend(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
            mask: Optional[jax.Array]) -> jax.Array:
    """q (B,Sq,H,D), k/v (B,Sk,Kv,D) -> (B,Sq,H*D).  GQA via head grouping;
    softmax in f32; optional gemma2 attn-logit softcap."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    q = q.reshape(b, sq, kvh, group, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (1.0 / jnp.sqrt(jnp.float32(d)))
    if cfg.attn_softcap is not None:
        logits = softcap(logits, cfg.attn_softcap)
    if mask is not None:
        # mask broadcasting: (Sq, Sk) or (B, Sq, Sk) -> (B?, 1, 1, Sq, Sk)
        if mask.ndim == 2:
            mask = mask[None, None, None]
        elif mask.ndim == 3:
            mask = mask[:, None, None]
        logits = jnp.where(mask, logits, MASK_VALUE)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    probs = probs.astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h * d)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention: online softmax over kv tiles
# ---------------------------------------------------------------------------

_M_INIT = -1.0e30


def _tile_mask(qoff, koff, tq, tk, causal, window):
    if not causal and window is None:
        return None
    qpos = qoff + jnp.arange(tq)[:, None]
    kpos = koff + jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _attend_blockwise(cfg: ModelConfig, q, k, v, *, causal: bool,
                      window: Optional[int]) -> jax.Array:
    """Tiled attention, never materializing (Sq, Sk).

    q (B,Sq,H,D), k/v (B,Sk,KvH,D) -> (B,Sq,H*D).  Tile sizes from the
    config; online softmax carries (m, l, acc) in f32 across kv tiles.

    Two loop modes:
      - ``cfg.unroll_scan``: python loops with tile SKIPPING (causal /
        window) — the true FLOP schedule of a flash kernel, used by the
        dry-run cost pass;
      - default: ``lax.scan`` over q tiles x kv tiles with in-tile masking
        — compact HLO for the production compile.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    tq = min(cfg.flash_block_q, sq)
    tk = min(cfg.flash_block_kv, sk)
    nq, nk = sq // tq, sk // tk
    assert nq * tq == sq and nk * tk == sk, (sq, sk, tq, tk)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qr = jnp.moveaxis(q.reshape(b, nq, tq, kvh, g, d), 1, 0)   # (nq,B,..)
    kr = jnp.moveaxis(k.reshape(b, nk, tk, kvh, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, tk, kvh, d), 1, 0)

    def kv_step(qt, carry, kt, vt, qoff, koff):
        m, l, acc = carry
        s = jnp.einsum("bqkgd,bskd->bkgqs", qt, kt,
                       preferred_element_type=jnp.float32) * scale
        if cfg.attn_softcap is not None:
            s = softcap(s, cfg.attn_softcap)
        mask = _tile_mask(qoff, koff, tq, tk, causal, window)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, _M_INIT)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])                      # <= 1
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vt,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    def q_tile(qi, qt):
        qoff = qi * tq
        m0 = jnp.full((b, kvh, g, tq), _M_INIT, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, tq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, tq, d), jnp.float32)
        if cfg.unroll_scan:
            carry = (m0, l0, a0)
            for ki in range(nk):
                koff = ki * tk
                if causal and koff > qoff + tq - 1:
                    continue            # tile strictly above the diagonal
                if window is not None and koff + tk - 1 <= qoff - window:
                    continue            # tile strictly outside the window
                carry = kv_step(qt, carry, kr[ki], vr[ki], qoff, koff)
            m, l, acc = carry
        else:
            def body(carry, inp):
                ki, kt, vt = inp
                return kv_step(qt, carry, kt, vt, qoff, ki * tk), None

            (m, l, acc), _ = lax.scan(
                body, (m0, l0, a0),
                (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]           # (b,kvh,g,tq,d)
        return jnp.moveaxis(out, 3, 1).reshape(b, tq, h * d)

    if cfg.unroll_scan:
        tiles = [q_tile(i, qr[i]) for i in range(nq)]
        out = jnp.concatenate(tiles, axis=1)
    else:
        def outer(_, inp):
            qi, qt = inp
            return None, q_tile(qi, qt)

        _, tiles = lax.scan(outer, None, (jnp.arange(nq), qr))
        out = jnp.moveaxis(tiles, 0, 1).reshape(b, sq, h * d)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Train / prefill paths
# ---------------------------------------------------------------------------

def self_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array, *, window: Optional[int] = None,
                   return_cache: bool = False):
    """Full-sequence self-attention.  x (B,S,d), positions (B,S)."""
    b, s, _ = x.shape
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q, k = _qk_norm(cfg, p, q, k)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if s >= cfg.flash_threshold:
        # 'q_full'/'kv_full' hints (no-ops unless a rule is installed):
        # let a driver pin the Q/K/V layouts ONCE before the tile loops —
        # e.g. gather an hd-sharded MQA KV (or head-sharded Q) here
        # instead of per flash tile (§Perf cell 2).
        q = hints.constrain(q, "q_full")
        k = hints.constrain(k, "kv_full")
        v = hints.constrain(v, "kv_full")
        out = _attend_blockwise(cfg, q, k, v, causal=cfg.causal,
                                window=window)
    else:
        if not cfg.causal:
            mask = None
        elif window is not None:
            mask = common.window_mask(s, s, 0, window)
        else:
            mask = common.causal_mask(s, s, 0)
        out = _attend(cfg, q, k, v, mask)
    y = out @ p["wo"]
    if cfg.attn_bias:
        y = y + p["bo"]
    if not return_cache:
        return y, None
    if window is not None:
        # Keep only the trailing `window` positions in a ring buffer whose
        # slot i holds absolute position  s - window + i  (mod window wraps
        # transparently because we also store slot positions).
        w = min(window, s)
        ck = k[:, s - w:]
        cv = v[:, s - w:]
        cpos = jnp.broadcast_to(jnp.arange(s - w, s, dtype=jnp.int32), (b, w))
        if w < window:  # pad unfilled slots (only when S < window)
            pad = window - w
            ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.pad(cpos, ((0, 0), (0, pad)), constant_values=-1)
        cache = {"k": ck, "v": cv, "slot_pos": cpos}
    else:
        cache = {"k": k, "v": v}
    return y, cache


def cross_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                    kv_states: Optional[jax.Array] = None,
                    kv_cache: Optional[dict] = None,
                    return_cache: bool = False):
    """Cross-attention to (static) vision states: no RoPE, no causal mask."""
    if kv_cache is not None:
        k, v = kv_cache["k"], kv_cache["v"]
    else:
        k, v = _project_kv(cfg, p, kv_states)
    q = _project_q(cfg, p, x)
    if cfg.qk_norm:
        q = common.rms_head_norm(q, p["q_norm"])
        if kv_cache is None:
            k = common.rms_head_norm(k, p["k_norm"])
    out = _attend(cfg, q, k, v, mask=None)
    y = out @ p["wo"]
    if cfg.attn_bias:
        y = y + p["bo"]
    cache = {"k": k, "v": v} if return_cache else None
    return y, cache


# ---------------------------------------------------------------------------
# Decode paths (single new token against a cache)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, s_max: int,
                      window: Optional[int] = None) -> dict:
    dt = dtype_of(cfg)
    s = min(window, s_max) if window is not None else s_max
    cache = {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dt),
    }
    if window is not None:
        cache["slot_pos"] = jnp.full((batch, s), -1, jnp.int32)
    return cache


def _decode_attend_blockwise(cfg: ModelConfig, q, k, v, pos) -> jax.Array:
    """Flash-decoding: one query against a long cache, tiled over KV.

    q (B,1,H,D); k/v (B,S,KvH,D) with S >= cfg.flash_threshold.  A scan
    over KV chunks carries the online-softmax (m, l, acc) — the 32k/500k
    cache is only ever touched one chunk at a time (split-KV), so neither
    the f32 score row nor any dtype upcast of the cache materializes at
    full length.
    """
    b, _, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    tk = min(cfg.flash_block_kv, s)
    nk = s // tk
    assert nk * tk == s, (s, tk)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(b, 1, kvh, g, d)

    def chunk(arr, ki):
        # dynamic_slice keeps the cache a loop-invariant operand — no
        # transposed full-cache copy enters the scan.
        return lax.dynamic_slice_in_dim(arr, ki * tk, tk, axis=1)

    def body(carry, ki):
        m, l, acc = carry
        kt, vt = chunk(k, ki), chunk(v, ki)
        sres = jnp.einsum("bqkgd,bskd->bkgqs", qg, kt,
                          preferred_element_type=jnp.float32) * scale
        if cfg.attn_softcap is not None:
            sres = softcap(sres, cfg.attn_softcap)
        kv_pos = ki * tk + jnp.arange(tk)
        sres = jnp.where((kv_pos <= pos)[None, None, None, None], sres,
                         _M_INIT)
        m_new = jnp.maximum(m, jnp.max(sres, axis=-1))
        pmat = jnp.exp(sres - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(pmat, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", pmat.astype(v.dtype), vt,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, 1), _M_INIT, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, 1), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, 1, d), jnp.float32)
    if cfg.unroll_scan:   # dry-run cost pass: true per-chunk FLOPs
        carry = (m0, l0, a0)
        for ki in range(nk):
            carry, _ = body(carry, jnp.int32(ki))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).reshape(b, 1, h * d).astype(v.dtype)


def decode_self_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                          cache: dict, pos: jax.Array,
                          window: Optional[int] = None):
    """One-token decode.  x (B,1,d); pos () int32 absolute position of the
    new token; cache as produced by init_decode_cache/self_attention."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q = _project_q(cfg, p, x)
    k_new, v_new = _project_kv(cfg, p, x)
    q, k_new = _qk_norm(cfg, p, q, k_new)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    # Never let a (possibly f32) new row promote the whole cache: the DUS
    # must stay in the cache dtype or a 32k-context cache silently doubles.
    k_new = k_new.astype(cache["k"].dtype)
    v_new = v_new.astype(cache["v"].dtype)
    if window is not None:
        slot = jnp.mod(pos, cache["k"].shape[1])
        k = lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
        v = lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
        slot_pos = lax.dynamic_update_slice_in_dim(
            cache["slot_pos"], positions, slot, axis=1)
        keep = (slot_pos > pos - window) & (slot_pos >= 0) & (slot_pos <= pos)
        mask = keep[:, None, :]                              # (B, 1, W)
        new_cache = {"k": k, "v": v, "slot_pos": slot_pos}
    else:
        k = lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
        v = lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
        new_cache = {"k": k, "v": v}
        if k.shape[1] >= cfg.flash_threshold:
            # flash-decoding: split-KV online softmax over the long cache
            out = _decode_attend_blockwise(cfg, q, k, v, pos)
            y = out @ p["wo"]
            if cfg.attn_bias:
                y = y + p["bo"]
            return y, new_cache
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        mask = (kv_pos <= pos)[None, None, :]                # (1, 1, S)

    out = _attend(cfg, q, k, v, mask)
    y = out @ p["wo"]
    if cfg.attn_bias:
        y = y + p["bo"]
    return y, new_cache
