"""Small classifiers for the paper-faithful experiments (LeNet-scale).

The paper's own models are LeNet (MNIST) and ResNet18 (CIFAR/ImageNet); the
repro experiments here use an MLP / LeNet-style CNN on structured synthetic
data (no image datasets ship offline).  What matters to GRAD-MATCH is the
interface these expose: ``apply`` returns (logits, last_hidden) so the
selection proxies (last-layer gradients, paper §4) are closed-form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.paper import ClassifierConfig
from repro.models import common


def init_classifier(cfg: ClassifierConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, len(cfg.hidden) + 3)
    p: dict = {}
    if cfg.kind == "cnn":
        h, w, c = cfg.image_shape
        p["conv1"] = common.dense_init(ks[0], (5, 5, c, 6), jnp.float32,
                                       fan_in=25 * c)
        p["conv2"] = common.dense_init(ks[1], (5, 5, 6, 16), jnp.float32,
                                       fan_in=25 * 6)
        flat = (h // 4 - 3) * (w // 4 - 3) * 16
        dims = (flat,) + cfg.hidden
    else:
        dims = (cfg.in_dim,) + cfg.hidden
    for i in range(len(dims) - 1):
        p[f"fc{i}"] = {
            "w": common.dense_init(ks[2 + i], (dims[i], dims[i + 1]),
                                   jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    p["head"] = {
        "w": common.dense_init(ks[-1], (dims[-1], cfg.num_classes),
                               jnp.float32),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return p


def apply_classifier(cfg: ClassifierConfig, p: dict, x: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, C), last_hidden (B, d)) — the hidden feeding the
    final linear layer, which the GRAD-MATCH proxies need."""
    act = common.activation(cfg.act)
    if cfg.kind == "cnn":
        h = lax.conv_general_dilated(
            x, p["conv1"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = act(h)
        h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
        h = lax.conv_general_dilated(
            h, p["conv2"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = act(h)
        h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
    else:
        h = x
    i = 0
    while f"fc{i}" in p:
        h = act(h @ p[f"fc{i}"]["w"] + p[f"fc{i}"]["b"])
        i += 1
    logits = h @ p["head"]["w"] + p["head"]["b"]
    return logits, h


def classifier_loss(cfg: ClassifierConfig, p: dict, batch: dict
                    ) -> tuple[jax.Array, dict]:
    """Weighted CE (same weighted-subset objective as lm_loss)."""
    logits, _ = apply_classifier(cfg, p, batch["x"])
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    own = jnp.take_along_axis(lg, batch["y"][:, None], axis=-1)[:, 0]
    ce = lse - own                                            # (B,)
    w = batch.get("weights")
    if w is None:
        w = jnp.full(ce.shape, 1.0 / ce.shape[0], jnp.float32)
    loss = jnp.sum(w * ce)
    acc = jnp.mean((jnp.argmax(lg, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc, "ce": jnp.mean(ce)}
