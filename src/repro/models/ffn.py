"""Dense FFN: plain 2-matrix MLP (gelu/silu) or gated 3-matrix (geglu/swiglu).

Weight layout is sharding-friendly: up/gate are (d_model, d_ff) —
column-parallel over the ``model`` axis — and down is (d_ff, d_model) —
row-parallel (GSPMD inserts the reduce at the down matmul).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dtype_of


def init_ffn(cfg: ModelConfig, key: jax.Array, d_ff: int | None = None
             ) -> dict:
    dt = dtype_of(cfg)
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": common.dense_init(k1, (cfg.d_model, d_ff), dt),
        "w_down": common.dense_init(k2, (d_ff, cfg.d_model), dt, fan_in=d_ff),
    }
    if common.is_gated(cfg.act):
        p["w_gate"] = common.dense_init(k3, (cfg.d_model, d_ff), dt)
    return p


def ffn_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    act = common.activation(cfg.act)
    if common.is_gated(cfg.act):
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_up"])
    return h @ p["w_down"]
