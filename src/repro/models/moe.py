"""Mixture-of-Experts FFN with capacity-bounded sparse-index dispatch.

Routing (top-k over softmax router probs, renormalized) follows
Switch/Mixtral; dispatch is the memory-optimal *sparse-index* form rather
than the GShard one-hot einsum: per token group we build an (E, C) table of
token ids by stable-sorting the (T*k,) expert assignments, gather the routed
activations to (E, C, d), run the expert FFNs as one batched einsum against
the (E, d, ff) expert weights, and scatter-add back with the combine weights.

Memory is O(routed_tokens * d) — the one-hot dispatch tensor (T, E, C) that
made CRAIG-era MoE impls OOM never exists.  Expert-parallelism: the expert
weights' leading E axis shards over the ``model`` mesh axis; the
``hints.constrain`` calls let drivers pin the (G, E, C, d) routed activations
to ('data', 'model', None, None), which GSPMD realizes as the canonical
all-to-all at dispatch and combine.

Token groups: training/prefill treat each sequence as a group (routing and
capacity are per-sequence, G = batch); decode treats the whole batch as one
group.  Capacity C = ceil(cf * T_g * k / E), >= 4 for lane alignment.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import hints
from repro.models import common
from repro.models.common import dtype_of


def init_moe(cfg: ModelConfig, key: jax.Array) -> dict:
    m = cfg.moe
    dt = dtype_of(cfg)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, m.d_ff, m.n_experts
    p = {
        "router": common.dense_init(kr, (d, e), jnp.float32),
        "w_gate": common.dense_init(kg, (e, d, ff), dt, fan_in=d),
        "w_up": common.dense_init(ku, (e, d, ff), dt, fan_in=d),
        "w_down": common.dense_init(kd, (e, ff, d), dt, fan_in=ff),
    }
    if m.n_shared_experts:
        from repro.models import ffn
        shared_cfg = cfg.replace(act="swiglu")
        p["shared"] = ffn.init_ffn(shared_cfg, ks,
                                   d_ff=m.d_ff * m.n_shared_experts)
    return p


def capacity_of(cfg: ModelConfig, group_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(m.capacity_factor * group_tokens * m.top_k / m.n_experts)
    return max(4, min(c, group_tokens))


def _route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """x (G, T, d) -> top-k (idx, weight) per token + load-balance aux loss."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ router_w                  # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, m.top_k)                   # (G, T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e  (per group, then
    # averaged) — f_e = fraction of tokens whose top-1 is e, p_e = mean prob.
    f = jnp.mean(jax.nn.one_hot(top_i[..., 0], m.n_experts), axis=1)  # (G, E)
    pbar = jnp.mean(probs, axis=1)                                    # (G, E)
    aux = m.n_experts * jnp.mean(jnp.sum(f * pbar, axis=-1))
    return top_i, top_w.astype(x.dtype), aux


def _dispatch_indices(eid: jax.Array, w: jax.Array, n_experts: int,
                      capacity: int):
    """eid/w (T, k) -> idx (E, C) token ids (sentinel=T), cw (E, C) weights.

    Stable sort groups assignments by expert; rank-within-expert beyond the
    capacity is dropped (classic capacity truncation, arrival order).
    """
    t, k = eid.shape
    flat_e = eid.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_s, t_s, w_s = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    start = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - start[e_s]
    idx = jnp.full((n_experts, capacity), t, jnp.int32)
    idx = idx.at[e_s, rank].set(t_s, mode="drop")
    cw = jnp.zeros((n_experts, capacity), w.dtype)
    cw = cw.at[e_s, rank].set(w_s, mode="drop")
    return idx, cw


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array,
              group: str = "seq") -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss ()).

    group='seq': one routing group per sequence (train/prefill);
    group='batch': single group over all tokens (decode, S==1).
    """
    m = cfg.moe
    b, s, d = x.shape
    if group == "batch":
        xg = x.reshape(1, b * s, d)
    else:
        xg = x.reshape(b, s, d)
    g, t, _ = xg.shape
    cap = capacity_of(cfg, t)

    top_i, top_w, aux = _route(cfg, p["router"], xg)
    idx, cw = jax.vmap(
        lambda e, w: _dispatch_indices(e, w, m.n_experts, cap)
    )(top_i, top_w)                                          # (G,E,C) x2

    # Gather routed tokens; sentinel t -> zero row.
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jax.vmap(lambda xp, ix: xp[ix])(xpad, idx)          # (G, E, C, d)
    xe = hints.constrain(xe, "moe_dispatch")

    # Expert FFN (always gated/swiglu in the assigned MoE archs).
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = hints.constrain(ye, "moe_combine")

    # Scatter-add back with combine weights (sentinel rows dropped).
    def _combine(y_e, ix, w_e):
        out = jnp.zeros((t, d), ye.dtype)
        return out.at[ix.reshape(-1)].add(
            (y_e * w_e[..., None]).reshape(-1, d), mode="drop")

    y = jax.vmap(_combine)(ye, idx, cw)                      # (G, T, d)
    y = y.reshape(b, s, d)

    if m.n_shared_experts:
        from repro.models import ffn
        shared_cfg = cfg.replace(act="swiglu")
        y = y + ffn.ffn_apply(shared_cfg, p["shared"], x)
    return y, aux * m.router_aux_weight
