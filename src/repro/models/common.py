"""Shared model primitives: norms, activations, RoPE, init, dtype policy.

Everything is functional: ``init_*`` builds a params pytree (plain dicts of
jnp arrays), ``*_apply`` consumes it.  Mixed-precision policy: parameters are
stored in ``cfg.param_dtype`` (bf16 for the big archs), matmuls run in the
param dtype, and numerically-sensitive reductions (norm statistics, softmax,
gate cumsums, the final loss) run in f32.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    """Truncated-normal fan-in init (the LM-standard 1/sqrt(fan_in))."""
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (params always f32: tiny, and scale precision matters)
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:  # rmsnorm  (gemma convention: scale enters as (1 + s))
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head qk-norm (qwen3): normalize over the head_dim axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "relu": jax.nn.relu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
        # gate nonlinearity of the gated variants:
        "geglu": lambda x: jax.nn.gelu(x, approximate=True),
        "swiglu": jax.nn.silu,
    }[name]


def is_gated(name: str) -> bool:
    return name in ("geglu", "swiglu")


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) int32 -> rotated x (same dtype).

    Pairs (x[..., :D/2], x[..., D/2:]) — the 'split-half' convention (llama).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """(q_len, kv_len) bool mask: True = attend.  q_offset = absolute position
    of query row 0 (int or traced scalar)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def window_mask(q_len: int, kv_len: int, q_offset, window: int) -> jax.Array:
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return (kv_pos <= q_pos) & (kv_pos > q_pos - window)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
