"""Quickstart: GRAD-MATCH in 60 seconds.

Selects a weighted coreset of a synthetic classification set with OMP,
shows the gradient-matching error against random selection, then trains
on the subset.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import PaperHParams, mlp
from repro.core.gradmatch import gradmatch
from repro.core.random_sel import random_select
from repro.data.synthetic import make_classification, split
from repro.train.trainer import AdaptiveTrainer, TrainerConfig


def main():
    # 1) data + a gradient-proxy matrix (here: raw features x residual
    #    direction stand-in — the trainer uses real last-layer gradients)
    ds = make_classification(jax.random.PRNGKey(0), n=2048, dim=32,
                             num_classes=10)
    train, val = split(ds, jax.random.PRNGKey(1))

    # 2) one OMP selection round on explicit gradient proxies
    g = train.x / jnp.linalg.norm(train.x, axis=1, keepdims=True)
    target = jnp.sum(g, axis=0)
    k = 128
    sel = gradmatch(g, k=k, lam=0.5)
    n = train.n

    def rel_err(s):
        """Error at the optimal scalar rescale (weights are normalized to
        sum 1; training renormalizes per batch, so direction is what
        matters)."""
        approx = jnp.sum(jnp.where(s.mask, s.weights, 0.0)[:, None]
                         * g[jnp.where(s.mask, s.indices, 0)], axis=0)
        scale = jnp.sum(approx * target) / jnp.maximum(
            jnp.sum(approx * approx), 1e-12)
        return float(jnp.linalg.norm(scale * approx - target)
                     / jnp.linalg.norm(target))

    e_gm = rel_err(sel)
    rnd = random_select(jax.random.PRNGKey(2), n, k)
    e_rnd = rel_err(rnd)
    print(f"selected {int(sel.mask.sum())}/{n} examples | rel matching "
          f"error: gradmatch {e_gm:.3f} vs random {e_rnd:.3f}")

    # 3) adaptive training on GRAD-MATCHPB subsets (paper Alg. 1)
    tc = TrainerConfig(strategy="gradmatch-pb", budget=0.15, epochs=30,
                       batch_size=64, hp=PaperHParams(select_every=10))
    rep = AdaptiveTrainer(mlp(in_dim=32, num_classes=10), tc, train,
                          val).run()
    tc_r = TrainerConfig(strategy="random", budget=0.15, epochs=30,
                         batch_size=64, hp=PaperHParams(select_every=10))
    rep_r = AdaptiveTrainer(mlp(in_dim=32, num_classes=10), tc_r, train,
                            val).run()
    print(f"GRAD-MATCHPB: acc={rep.final_acc:.3f}  "
          f"work={rep.work_units:.0f} (sel {rep.selection_seconds:.1f}s)")
    print(f"RANDOM      : acc={rep_r.final_acc:.3f}  "
          f"work={rep_r.work_units:.0f}")


if __name__ == "__main__":
    main()
