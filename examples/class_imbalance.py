"""Class-imbalance robustness (paper §5, Figs. 3f/4e).

30% of classes lose 90% of their examples; a clean validation set is
available.  GRAD-MATCH with ``isValid=True`` matches the *validation*
gradient (paper Alg. 1 line 3) and should beat both training-gradient
matching and random — and can beat full training on the biased data.

Run:  PYTHONPATH=src python examples/class_imbalance.py
"""

import jax

from repro.configs.paper import PaperHParams, mlp
from repro.data.synthetic import make_imbalanced
from repro.train.trainer import AdaptiveTrainer, TrainerConfig


def main():
    train, val = make_imbalanced(jax.random.PRNGKey(5), n=4096, dim=32,
                                 num_classes=10, imbalanced_frac=0.3,
                                 keep_frac=0.1, sep=5.0)
    print(f"imbalanced train n={train.n}, clean val n={val.n}")
    model = mlp(in_dim=32, num_classes=10)
    hp = PaperHParams(select_every=10)

    runs = [
        ("full (biased data)", "full", False, 1.0),
        ("random 30%", "random", False, 0.3),
        ("gradmatch 30% (train-grad)", "gradmatch", False, 0.3),
        ("gradmatch 30% (VAL-grad)", "gradmatch", True, 0.3),
    ]
    print(f"{'run':32s} {'acc':>7} {'work':>10}")
    for name, strategy, is_valid, budget in runs:
        tc = TrainerConfig(strategy=strategy, budget=budget, epochs=40,
                           batch_size=64, is_valid=is_valid, hp=hp)
        rep = AdaptiveTrainer(model, tc, train, val).run()
        print(f"{name:32s} {rep.final_acc:7.3f} {rep.work_units:10.0f}")


if __name__ == "__main__":
    main()
