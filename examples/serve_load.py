"""Serving under load: overload-resilient selection (DESIGN.md §10).

Two tenants with unequal offered load (team-a sends 2/3 of the traffic
at weight 2, team-b 1/3 at weight 1) and a priority mix hit a
``SelectionService`` with one healthy resident pool and one
fault-injected chunked pool, as one open-loop Poisson burst on a virtual
clock.  The run prints per-tenant p99 latency, the degradation-rung
distribution (certified / prefix-shared / stochastic / shed), the
weighted fairness ratio, and the shed/refund accounting — and fails if
any accounting invariant (no lost tickets, no in-flight leaks, refunds
exactly once) is violated.

Run:  PYTHONPATH=src python examples/serve_load.py
      PYTHONPATH=src python examples/serve_load.py --smoke   # CI sizes
"""

import argparse

from repro.launch import serve_selection as serve_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pools (CI configuration)")
    ap.add_argument("--pool-size", type=int, default=2048)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in req/s (0 = saturating burst)")
    args = ap.parse_args(argv)
    cmd = ["--load", "--pool-size", str(args.pool_size),
           "--dim", str(args.dim), "--requests", str(args.requests),
           "--rate", str(args.rate), "--k", "64"]
    if args.smoke:
        cmd.append("--smoke")
    report = serve_driver.main(cmd)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
