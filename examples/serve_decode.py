"""Batched serving example: prefill + decode with KV caches.

Runs a smoke-reduced assigned architecture through launch/serve.py —
a queue of synthetic prompts, admission in fixed batches, greedy decode
against the cache (the same step functions the multi-pod dry-run lowers
at full scale).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-7b]
"""

import argparse

from repro.launch import serve as serve_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-7b")
    args = ap.parse_args(argv)
    for arch in [args.arch]:
        # prompt length divisible by the smoke configs' SSD/mLSTM chunk
        serve_driver.main(["--arch", arch, "--smoke", "--requests", "8",
                           "--batch", "4", "--prompt-len", "32",
                           "--gen-len", "12"])


if __name__ == "__main__":
    main()
