"""Continual-stream selection end to end (DESIGN.md §11).

A tenant opens an infinite-stream session against a
``SelectionService``, POSTs gradient batches forever (here: a fixed
number of seeded batches), and reads back the maintained coreset after
every push.  Mid-run the stream is killed and reopened from its
checkpoint — the resumed run must finish bit-identically to a reference
``BufferMaintainer`` that was never interrupted.  The run prints the
admission/eviction/downdate accounting, the tenant's charged units, and
the final differential check against a from-scratch OMP solve over the
surviving buffer rows — and fails if either the resume or the
differential diverges.

Run:  PYTHONPATH=src python examples/serve_stream.py
      PYTHONPATH=src python examples/serve_stream.py --smoke   # CI sizes
"""

import argparse
import tempfile

import numpy as np

from repro.continual import BufferMaintainer
from repro.core import omp
from repro.serve import SelectionService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI configuration)")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.dim, args.k, args.capacity = 16, 8, 64
        args.batch, args.batches = 16, 12

    rng = np.random.default_rng(args.seed)
    batches = [rng.standard_normal((args.batch, args.dim))
               .astype(np.float32) for _ in range(args.batches)]
    target = np.sum(np.concatenate(batches), axis=0)
    kill_at = args.batches // 2

    # Reference: one maintainer, never interrupted.
    ref = BufferMaintainer(capacity=args.capacity, d=args.dim,
                           target=target, k=args.k, seed=args.seed)
    gid = 0
    for b in batches:
        ref.admit(b, gids=np.arange(gid, gid + args.batch))
        gid += args.batch

    with tempfile.TemporaryDirectory(prefix="serve-stream-") as ckpt:
        svc = SelectionService()
        sid = svc.open_stream(d=args.dim, k=args.k, target=target,
                              capacity=args.capacity, tenant="team-a",
                              seed=args.seed, checkpoint_dir=ckpt)
        gid = 0
        for b in batches[:kill_at]:
            svc.push_stream(sid, b, gids=np.arange(gid, gid + args.batch))
            gid += args.batch
        svc.close_stream(sid)               # "kill" mid-stream
        sid = svc.open_stream(d=args.dim, k=args.k, target=target,
                              capacity=args.capacity, tenant="team-a",
                              seed=args.seed, checkpoint_dir=ckpt)
        res = None
        for b in batches[kill_at:]:
            res = svc.push_stream(sid, b,
                                  gids=np.arange(gid, gid + args.batch))
            gid += args.batch

        m = svc.streams.get(sid).maintainer
        resumed_ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(ref.slot_result(), m.slot_result()))

        pool, okmask = m.pool_view()
        fresh = omp.omp_session_start(pool, m.target, m.k, valid=okmask,
                                      block=m.block)
        idx, w, mask, _ = m.slot_result()
        diff_ok = (np.array_equal(np.asarray(idx),
                                  np.asarray(fresh.indices))
                   and np.allclose(np.asarray(w),
                                   np.asarray(fresh.weights),
                                   rtol=2e-4, atol=2e-5))

        tenant = svc.stats()["tenants"]["team-a"]
        print(f"serve_stream,batches={args.batches},rows="
              f"{args.batch * args.batches},k={args.k},"
              f"capacity={args.capacity},{res.stats.summary()}")
        print(f"serve_stream,tenant=team-a,"
              f"admitted={tenant['admitted']},"
              f"used_units={tenant['used_units']:.1f},"
              f"resumed_bit_exact={resumed_ok},diff_exact={diff_ok}")
        svc.close_stream(sid)

    ok = resumed_ok and diff_ok
    print(f"serve_stream,{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
