"""Artifact fast path end to end (DESIGN.md §12).

An offline pass solves a pool's anytime-OMP trajectory once and commits
it to a content-addressed ``ArtifactStore``; a serving process pointed
at the same store then answers every covered budget straight from disk
— verified, memoized, rung ``"artifact"``, off the drain path.  The run
then turns adversarial: a seeded bit-flip corrupts the artifact on
disk, a fresh service must *quarantine* it on first read and fall
through the live ladder to the identical selection — fail closed, never
a corrupt answer.  Prints the hit/miss/quarantine accounting and fails
if the differential or the fallback diverges.

Run:  PYTHONPATH=src python examples/serve_artifacts.py
      PYTHONPATH=src python examples/serve_artifacts.py --smoke  # CI
"""

import argparse
import tempfile

import numpy as np

from repro.artifacts import ArtifactStore, build_artifact
from repro.core.omp import omp_session_start
from repro.resilience import inject_disk_fault
from repro.serve import SelectionService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI configuration)")
    ap.add_argument("--pool-size", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.pool_size, args.dim, args.k_max = 512, 32, 32

    rng = np.random.default_rng(args.seed)
    g = rng.standard_normal((args.pool_size, args.dim)).astype(np.float32)

    with tempfile.TemporaryDirectory(prefix="artifact-store-") as root:
        # -- offline: solve once, commit the trajectory -------------------
        store = ArtifactStore(root)
        svc = SelectionService(artifact_store=store)
        pid = svc.register_pool(g)
        entry = svc.registry.get(pid)
        tgt = np.asarray(entry.target_sum, np.float32)
        _, ident = build_artifact(store, g, tgt, args.k_max,
                                  fingerprint=entry.content_digest)
        print(f"serve_artifacts,built={ident},pool={args.pool_size},"
              f"k_max={args.k_max}")

        # -- online: every covered budget served at submit ----------------
        hit_ok = True
        for k in (1, args.k_max // 2, args.k_max):
            t = svc.submit(pid, k)
            sess = omp_session_start(g, tgt, k)
            same = (t.status == "done"
                    and t.degradation == "artifact"
                    and np.array_equal(np.asarray(t.result.indices),
                                       np.asarray(sess.indices)))
            print(f"serve_artifacts,k={k},rung={t.degradation},"
                  f"bit_exact_vs_live={same}")
            hit_ok &= same
        reg = svc.stats()["registry"]
        print(f"serve_artifacts,hits={reg['artifact_hits']},"
              f"misses={reg['artifact_misses']},"
              f"quarantined={reg['artifact_quarantined']}")

        # -- adversary: flip one bit on disk ------------------------------
        info = inject_disk_fault(store, ident, "bit-flip", seed=args.seed)
        print(f"serve_artifacts,fault=bit-flip,blob={info['blob']},"
              f"byte={info['byte']},bit={info['bit']}")
        cold = SelectionService(artifact_store=ArtifactStore(root))
        cold_pid = cold.register_pool(g)
        t = cold.submit(cold_pid, args.k_max)
        if t.status != "done":
            cold.drain()
        sess = omp_session_start(g, tgt, args.k_max)
        reg = cold.stats()["registry"]
        fallback_ok = (t.status == "done"
                       and t.degradation != "artifact"
                       and reg["artifact_quarantined"] == 1
                       and np.array_equal(np.asarray(t.result.indices),
                                          np.asarray(sess.indices)))
        print(f"serve_artifacts,after_fault_rung={t.degradation},"
              f"quarantined={reg['artifact_quarantined']},"
              f"fail_closed_same_answer={fallback_ok}")

    ok = hit_ok and fallback_ok
    print(f"serve_artifacts,{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
