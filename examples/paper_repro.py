"""End-to-end paper reproduction driver (Fig. 3-style sweep, CPU scale).

Runs the strategy grid at several budgets with the paper's hyper-params
(R=20 scaled to the shorter schedule, lambda=0.5, kappa=1/2, SGD m=0.9
wd=5e-4 cosine) and prints the speedup-vs-relative-error scatter the paper
plots, plus the Wilcoxon-flavored pairwise win table.

Run:  PYTHONPATH=src python examples/paper_repro.py [--epochs 60]
"""

import argparse

import jax

from repro.configs.paper import PaperHParams, mlp
from repro.data.synthetic import make_classification, split
from repro.train.trainer import AdaptiveTrainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--budgets", default="0.1,0.3")
    args = ap.parse_args(argv)
    budgets = [float(b) for b in args.budgets.split(",")]

    ds = make_classification(jax.random.PRNGKey(0), n=args.n, dim=32,
                             num_classes=10, sep=5.0)
    train, val = split(ds, jax.random.PRNGKey(1))
    model = mlp(in_dim=32, num_classes=10)
    hp = PaperHParams(select_every=10)

    full = AdaptiveTrainer(model, TrainerConfig(
        strategy="full", budget=1.0, epochs=args.epochs, batch_size=64,
        hp=hp), train, val).run()
    print(f"{'strategy':22s} {'budget':>6} {'acc':>7} {'rel_err%':>9} "
          f"{'speedup':>8}")
    print(f"{'full':22s} {'100%':>6} {full.final_acc:7.3f} {0.0:9.2f} "
          f"{1.0:8.2f}")

    rows = []
    for budget in budgets:
        grid = [("random", False), ("glister", False), ("craig-pb", False),
                ("gradmatch", False), ("gradmatch-pb", False),
                ("gradmatch-pb", True)]
        for strategy, warm in grid:
            tc = TrainerConfig(strategy=strategy, budget=budget,
                               epochs=args.epochs, batch_size=64,
                               warm_start=warm, hp=hp)
            rep = AdaptiveTrainer(model, tc, train, val).run()
            speed = full.work_units / rep.work_units
            rel = (full.final_acc - rep.final_acc) * 100
            print(f"{rep.strategy:22s} {budget:6.0%} "
                  f"{rep.final_acc:7.3f} {rel:9.2f} {speed:8.2f}")
            rows.append((rep.strategy, budget, rep.final_acc))

    # pairwise wins (gradmatch variants vs baselines across budgets)
    gm = [a for s, _, a in rows if s.startswith("gradmatch")]
    base = [a for s, _, a in rows if not s.startswith("gradmatch")]
    if gm and base:
        wins = sum(1 for g in gm for b in base if g >= b)
        print(f"\ngradmatch-vs-baseline wins: {wins}/{len(gm) * len(base)}")


if __name__ == "__main__":
    main()
