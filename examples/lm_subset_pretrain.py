"""GRAD-MATCH for LM pre-training: the pod-scale recipe at CPU scale.

Wraps launch/train.py: a smoke-reduced assigned architecture trains on
GRAD-MATCHPB-selected micro-batches from the stateless token pipeline,
with selection proxies from the closed-form head gradient (no trunk
backprop) and the sharded OMP path.  Compares against random selection
of the same budget.

Run:  PYTHONPATH=src python examples/lm_subset_pretrain.py [--arch gemma-2b]
"""

import argparse

from repro.launch import train as train_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args(argv)

    common = ["--arch", args.arch, "--smoke", "--steps", str(args.steps),
              "--seq-len", "64", "--micro-batch", "4", "--window", "16",
              "--budget", "0.25", "--select-every", "30", "--lr", "1e-2"]
    print(f"== GRAD-MATCHPB subset pre-training ({args.arch}) ==")
    r_gm = train_driver.main(common + ["--strategy", "gradmatch-pb"])
    print(f"== RANDOM subset pre-training ({args.arch}) ==")
    r_rnd = train_driver.main(common + ["--strategy", "random"])

    d_gm = r_gm["loss_first"] - r_gm["loss_last"]
    d_rnd = r_rnd["loss_first"] - r_rnd["loss_last"]
    print(f"\nloss drop over {args.steps} steps: "
          f"gradmatch-pb {d_gm:.3f} vs random {d_rnd:.3f} "
          f"(selection overhead {r_gm['selection_s']:.1f}s)")


if __name__ == "__main__":
    main()
