"""Selection-as-a-service example: multi-tenant batched selection.

Mirrors ``examples/serve_decode.py`` for the selection side: a
``SelectionService`` with two registered pools serves a queue of eight
requests from two tenants (same-pool requests micro-batch into one
multi-target OMP solve), then one client extends its budget k -> k'
through an anytime session — a certified resume of the checkpointed
solver state, not a re-solve.

Run:  PYTHONPATH=src python examples/serve_selection.py
"""

import argparse

from repro.launch import serve_selection as serve_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool-size", type=int, default=2048)
    ap.add_argument("--k", type=int, default=128)
    args = ap.parse_args(argv)
    report = serve_driver.main([
        "--requests", "8", "--pools", "2", "--tenants", "2",
        "--pool-size", str(args.pool_size), "--k", str(args.k),
        "--k-extend", str(args.k + args.k // 2), "--smoke",
    ])
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
